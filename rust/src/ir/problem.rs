//! Generalized contraction problems.
//!
//! A [`Problem`] describes an arbitrary tensor contraction as
//!
//! - a set of named **iteration dims** with extents, each flagged as a
//!   *reduction* dim (summed over) or an *output* dim (indexes the result),
//! - two **input tensors**, each carrying a per-dim **access map**: the
//!   element stride the tensor address advances per step of that dim
//!   (`None` = the tensor is not indexed by the dim, i.e. full reuse),
//! - an output access map shared by the accumulator `T` and the final
//!   output `C`, plus an optional bias tensor and ReLU flag applied by the
//!   write-back nest (the MLP epilogue).
//!
//! Linear access maps cover every workload family here: plain and
//! transposed matmul, batched matmul, and convolutions (a conv input is
//! indexed by *two* dims with the same stride — `In[oh + kh]` is
//! `oh * stride + kh * stride` — so overlap needs no special casing).
//! Matmul is just one constructor among several; the paper's benchmark
//! suite (square-ish matmul, M, N, K in `{64, 80, ..., 256}`) lives in
//! `dataset.rs`, the multi-workload suites in `eval/workloads.rs`.

/// Maximum number of iteration dims a problem may declare. Bounded so
/// [`Problem`] stays `Copy` (fixed-size arrays) and executor index vectors
/// live on the stack.
pub const MAX_DIMS: usize = 6;

/// Handle for one iteration dim of a [`Problem`]: an index into the
/// problem's dim table. Extent, name, and reduction status are looked up
/// through the problem.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Dim(u8);

impl Dim {
    /// Handle for dim number `index` of a problem.
    pub const fn new(index: usize) -> Dim {
        Dim(index as u8)
    }

    /// Position of this dim in the problem's dim table.
    pub fn index(self) -> usize {
        self.0 as usize
    }

    /// Dim 0 of the matmul-layout constructors (`m`).
    pub const M: Dim = Dim(0);
    /// Dim 1 of the matmul-layout constructors (`n`).
    pub const N: Dim = Dim(1);
    /// Dim 2 of the matmul-layout constructors (`k`, the reduction).
    pub const K: Dim = Dim(2);
}

/// Per-dim metadata of one problem dim.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
struct DimInfo {
    name: &'static str,
    extent: usize,
    reduce: bool,
}

/// Linear access map of one tensor: element stride per dim, `0` meaning
/// the tensor is not indexed by that dim (full reuse). The address of an
/// element is `sum_d idx[d] * stride[d]`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub struct Access {
    strides: [usize; MAX_DIMS],
}

impl Access {
    /// The empty access map (indexed by no dim).
    pub const fn none() -> Access {
        Access { strides: [0; MAX_DIMS] }
    }

    /// Builder: set the stride for `d` (must be > 0).
    pub fn with(mut self, d: Dim, stride: usize) -> Access {
        assert!(stride > 0, "access stride must be > 0");
        self.strides[d.index()] = stride;
        self
    }

    /// Element stride w.r.t. `d`, `None` if the tensor is not indexed by it.
    pub fn stride(&self, d: Dim) -> Option<usize> {
        match self.strides[d.index()] {
            0 => None,
            s => Some(s),
        }
    }

    /// Element stride w.r.t. `d`, `0` if the tensor is not indexed by it.
    pub fn stride_or_zero(&self, d: Dim) -> usize {
        self.strides[d.index()]
    }

    /// Whether the tensor is indexed by `d` at all.
    pub fn indexed(&self, d: Dim) -> bool {
        self.strides[d.index()] != 0
    }

    /// Element offset of the point `idx` (the executor's address map).
    pub fn offset(&self, idx: &[usize; MAX_DIMS]) -> usize {
        let mut off = 0;
        for (i, &s) in self.strides.iter().enumerate() {
            off += idx[i] * s;
        }
        off
    }
}

/// One tensor of a problem: a display name plus its access map.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub struct TensorInfo {
    /// Display name used in rendered nests and reports.
    pub name: &'static str,
    /// Per-dim access map.
    pub access: Access,
}

/// Fixed-capacity list of tensors (no allocation in featurizer/cost-model
/// hot paths). Derefs to a slice.
#[derive(Clone, Copy, Debug)]
pub struct TensorList {
    items: [TensorInfo; 4],
    len: usize,
}

impl TensorList {
    fn new(items: &[TensorInfo]) -> TensorList {
        let mut arr = [TensorInfo::default(); 4];
        arr[..items.len()].copy_from_slice(items);
        TensorList { items: arr, len: items.len() }
    }
}

impl std::ops::Deref for TensorList {
    type Target = [TensorInfo];

    fn deref(&self) -> &[TensorInfo] {
        &self.items[..self.len]
    }
}

/// A tensor-contraction instance: iteration dims, input access maps, and
/// the write-back epilogue. `Copy + Eq + Hash` so nests and cache keys can
/// embed it directly.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Problem {
    kind: &'static str,
    n_dims: u8,
    dims: [DimInfo; MAX_DIMS],
    inputs: [TensorInfo; 2],
    /// Access map shared by the accumulator `T` and the output `C`
    /// (row-major over the output dims).
    out: Access,
    bias: Option<TensorInfo>,
    relu: bool,
}

impl Problem {
    fn base(kind: &'static str, dims: &[(&'static str, usize, bool)]) -> Problem {
        assert!(!dims.is_empty() && dims.len() <= MAX_DIMS);
        let mut di = [DimInfo::default(); MAX_DIMS];
        for (i, &(name, extent, reduce)) in dims.iter().enumerate() {
            assert!(extent > 0, "dim {name} extent must be > 0");
            di[i] = DimInfo { name, extent, reduce };
        }
        Problem {
            kind,
            n_dims: dims.len() as u8,
            dims: di,
            inputs: [TensorInfo::default(); 2],
            out: Access::none(),
            bias: None,
            relu: false,
        }
    }

    /// Plain matmul `C[m, n] = sum_k A[m, k] * B[k, n]`, row-major.
    pub fn matmul(m: usize, n: usize, k: usize) -> Problem {
        let mut p = Problem::base("mm", &[("m", m, false), ("n", n, false), ("k", k, true)]);
        p.inputs[0] = TensorInfo {
            name: "A",
            access: Access::none().with(Dim::M, k).with(Dim::K, 1),
        };
        p.inputs[1] = TensorInfo {
            name: "B",
            access: Access::none().with(Dim::K, n).with(Dim::N, 1),
        };
        p.out = Access::none().with(Dim::M, n).with(Dim::N, 1);
        p
    }

    /// Back-compat alias for [`Problem::matmul`] (the seed's only workload).
    pub fn new(m: usize, n: usize, k: usize) -> Problem {
        Problem::matmul(m, n, k)
    }

    /// Transposed-A matmul `C[m, n] = sum_k A[k, m] * B[k, n]` — same dims
    /// as matmul, different access map on `A` (column walk).
    pub fn matmul_transposed(m: usize, n: usize, k: usize) -> Problem {
        let mut p = Problem::matmul(m, n, k);
        p.kind = "mmt";
        p.inputs[0] = TensorInfo {
            name: "At",
            access: Access::none().with(Dim::K, m).with(Dim::M, 1),
        };
        p
    }

    /// MLP layer: matmul with a fused `C = relu(T + bias[n])` write-back.
    pub fn mlp(m: usize, n: usize, k: usize) -> Problem {
        let mut p = Problem::matmul(m, n, k);
        p.kind = "mlp";
        p.bias = Some(TensorInfo { name: "bias", access: Access::none().with(Dim::N, 1) });
        p.relu = true;
        p
    }

    /// Builder: attach a fused bias-add epilogue along output dim `d`
    /// (`C = T + bias[d]` in the write-back nest). The graph fusion
    /// rewrite uses this to fold an elementwise bias-add producer into
    /// its consumer, generalizing the hardcoded [`Problem::mlp`]
    /// epilogue. `d` must be an output dim written at unit stride, so the
    /// epilogue is recoverable from the problem id alone (the spec
    /// parser re-attaches it to the unique unit-stride output dim).
    pub fn with_bias(mut self, d: Dim) -> Problem {
        assert!(!self.is_reduce(d), "bias dim must be an output dim");
        assert_eq!(self.out.stride(d), Some(1), "bias dim must have unit output stride");
        self.bias = Some(TensorInfo { name: "bias", access: Access::none().with(d, 1) });
        self
    }

    /// Builder: attach a fused ReLU epilogue (`C = max(T, 0)`, applied
    /// after the bias-add when both are present).
    pub fn with_relu(mut self) -> Problem {
        self.relu = true;
        self
    }

    /// Batched matmul `C[b, m, n] = sum_k A[b, m, k] * B[b, k, n]`.
    pub fn batched_matmul(b: usize, m: usize, n: usize, k: usize) -> Problem {
        let mut p = Problem::base(
            "bmm",
            &[("b", b, false), ("m", m, false), ("n", n, false), ("k", k, true)],
        );
        let (db, dm, dn, dk) = (Dim::new(0), Dim::new(1), Dim::new(2), Dim::new(3));
        p.inputs[0] = TensorInfo {
            name: "A",
            access: Access::none().with(db, m * k).with(dm, k).with(dk, 1),
        };
        p.inputs[1] = TensorInfo {
            name: "B",
            access: Access::none().with(db, k * n).with(dk, n).with(dn, 1),
        };
        p.out = Access::none().with(db, m * n).with(dm, n).with(dn, 1);
        p
    }

    /// 1-D convolution with channels:
    /// `C[oh, oc] = sum_{kw, ic} In[oh + kw, ic] * W[oc, kw, ic]`.
    /// The input is indexed by `oh` and `kw` with the *same* stride — the
    /// overlapping window expressed as a linear access map.
    pub fn conv1d(oh: usize, oc: usize, kw: usize, ic: usize) -> Problem {
        let mut p = Problem::base(
            "conv1d",
            &[("oh", oh, false), ("oc", oc, false), ("kw", kw, true), ("ic", ic, true)],
        );
        let (doh, doc, dkw, dic) = (Dim::new(0), Dim::new(1), Dim::new(2), Dim::new(3));
        p.inputs[0] = TensorInfo {
            name: "In",
            access: Access::none().with(doh, ic).with(dkw, ic).with(dic, 1),
        };
        p.inputs[1] = TensorInfo {
            name: "W",
            access: Access::none().with(doc, kw * ic).with(dkw, ic).with(dic, 1),
        };
        p.out = Access::none().with(doh, oc).with(doc, 1);
        p
    }

    /// Single-channel 2-D convolution:
    /// `C[oh, ow] = sum_{kh, kw} In[oh + kh, ow + kw] * W[kh, kw]`.
    pub fn conv2d(oh: usize, ow: usize, kh: usize, kw: usize) -> Problem {
        let mut p = Problem::base(
            "conv2d",
            &[("oh", oh, false), ("ow", ow, false), ("kh", kh, true), ("kw", kw, true)],
        );
        let (doh, dow, dkh, dkw) = (Dim::new(0), Dim::new(1), Dim::new(2), Dim::new(3));
        let iw = ow + kw - 1; // input row length
        p.inputs[0] = TensorInfo {
            name: "In",
            access: Access::none().with(doh, iw).with(dkh, iw).with(dow, 1).with(dkw, 1),
        };
        p.inputs[1] =
            TensorInfo { name: "W", access: Access::none().with(dkh, kw).with(dkw, 1) };
        p.out = Access::none().with(doh, ow).with(dow, 1);
        p
    }

    /// Fully custom contraction: named dims (`(name, extent, is_reduce)`),
    /// two input access maps, and the output access map. The output map
    /// must index exactly the non-reduction dims, and at least one dim
    /// must be an output dim (every nest carries a write-back over them).
    /// This is the extension point for workload families without a
    /// dedicated constructor (and lets tests build problems that exercise
    /// specific stride signatures).
    pub fn custom(
        kind: &'static str,
        dims: &[(&'static str, usize, bool)],
        in0: (&'static str, Access),
        in1: (&'static str, Access),
        out: Access,
    ) -> Problem {
        let mut p = Problem::base(kind, dims);
        p.inputs[0] = TensorInfo { name: in0.0, access: in0.1 };
        p.inputs[1] = TensorInfo { name: in1.0, access: in1.1 };
        p.out = out;
        // Every nest has a write-back over the output dims, so a problem
        // must have at least one (a full scalar reduction has none and
        // would lower to an empty write-back nest).
        assert!(
            p.dims().any(|d| !p.is_reduce(d)),
            "problem must have at least one output dim"
        );
        for d in p.dims() {
            if p.is_reduce(d) {
                assert!(
                    !out.indexed(d),
                    "reduction dim {} must not index the output",
                    p.dim_name(d)
                );
            } else {
                assert!(
                    out.indexed(d),
                    "output dim {} must index the output",
                    p.dim_name(d)
                );
            }
        }
        p
    }

    /// Workload family tag (`"mm"`, `"bmm"`, `"conv1d"`, ...).
    pub fn kind(&self) -> &'static str {
        self.kind
    }

    /// Number of iteration dims.
    pub fn n_dims(&self) -> usize {
        self.n_dims as usize
    }

    /// All dim handles, in declaration order (output dims first by
    /// constructor convention).
    pub fn dims(&self) -> impl Iterator<Item = Dim> {
        (0..self.n_dims).map(Dim)
    }

    /// Extent of `d`.
    pub fn extent(&self, d: Dim) -> usize {
        self.dims[d.index()].extent
    }

    /// Display name of `d`.
    pub fn dim_name(&self, d: Dim) -> &'static str {
        self.dims[d.index()].name
    }

    /// Whether `d` is a reduction dim (summed over, absent from the output).
    pub fn is_reduce(&self, d: Dim) -> bool {
        self.dims[d.index()].reduce
    }

    /// Output (non-reduction) dims, in declaration order.
    pub fn output_dims(&self) -> impl Iterator<Item = Dim> + '_ {
        self.dims().filter(move |&d| !self.is_reduce(d))
    }

    /// The two input tensors.
    pub fn inputs(&self) -> &[TensorInfo; 2] {
        &self.inputs
    }

    /// Access map of the accumulator/output.
    pub fn out_access(&self) -> &Access {
        &self.out
    }

    /// The accumulator written by the compute nest.
    pub fn accumulator(&self) -> TensorInfo {
        TensorInfo { name: "T", access: self.out }
    }

    /// The final output written by the write-back nest.
    pub fn output(&self) -> TensorInfo {
        TensorInfo { name: "C", access: self.out }
    }

    /// Optional bias tensor read by the write-back nest.
    pub fn bias(&self) -> Option<&TensorInfo> {
        self.bias.as_ref()
    }

    /// Whether the write-back applies ReLU.
    pub fn relu(&self) -> bool {
        self.relu
    }

    /// Tensors accessed by the compute nest (inputs + accumulator).
    pub fn compute_tensors(&self) -> TensorList {
        TensorList::new(&[self.inputs[0], self.inputs[1], self.accumulator()])
    }

    /// Tensors accessed by the write-back nest (T, optional bias, C).
    pub fn writeback_tensors(&self) -> TensorList {
        match self.bias {
            Some(b) => TensorList::new(&[self.accumulator(), b, self.output()]),
            None => TensorList::new(&[self.accumulator(), self.output()]),
        }
    }

    /// Number of elements of a tensor with access map `a`: the largest
    /// reachable offset plus one.
    pub fn access_len(&self, a: &Access) -> usize {
        let mut len = 1;
        for d in self.dims() {
            len += (self.extent(d) - 1) * a.stride_or_zero(d);
        }
        len
    }

    /// Number of elements of tensor `t`.
    pub fn tensor_len(&self, t: &TensorInfo) -> usize {
        self.access_len(&t.access)
    }

    /// Elements of the accumulator/output.
    pub fn out_len(&self) -> usize {
        self.access_len(&self.out)
    }

    /// Total iteration-space volume (product of all extents).
    pub fn iter_space(&self) -> u64 {
        self.dims().map(|d| self.extent(d) as u64).product()
    }

    /// Floating-point operations of the contraction (mul + add per point).
    pub fn flops(&self) -> u64 {
        2 * self.iter_space()
    }

    /// Bytes touched at least once (inputs + bias + accumulator + output),
    /// f32.
    pub fn footprint_bytes(&self) -> u64 {
        let bias = self.bias.map(|b| self.tensor_len(&b)).unwrap_or(0);
        4 * (self.tensor_len(&self.inputs[0])
            + self.tensor_len(&self.inputs[1])
            + bias
            + 2 * self.out_len()) as u64
    }

    /// Stable identifier, e.g. `mm_64x80x96` or `conv2d_28x28x3x3`.
    /// Fused epilogues are part of the identity: a non-mlp problem with a
    /// bias and/or ReLU epilogue (see [`Problem::with_bias`] /
    /// [`Problem::with_relu`]) appends `+bias` / `+relu` flags, e.g.
    /// `mm_64x80x96+bias+relu`, so fused and unfused variants never share
    /// a store key. `mlp` carries both epilogues by construction and
    /// stays bare (`mlp_64x80x96`).
    pub fn id(&self) -> String {
        let exts: Vec<String> = self.dims().map(|d| self.extent(d).to_string()).collect();
        let mut id = format!("{}_{}", self.kind, exts.join("x"));
        if self.kind != "mlp" {
            if self.bias.is_some() {
                id.push_str("+bias");
            }
            if self.relu {
                id.push_str("+relu");
            }
        }
        id
    }

    /// `(m, n, k)` when this is a *plain* matmul problem.
    pub fn as_matmul(&self) -> Option<(usize, usize, usize)> {
        if self.kind == "mm" {
            Some((self.extent(Dim::M), self.extent(Dim::N), self.extent(Dim::K)))
        } else {
            None
        }
    }

    /// `(m, n, k)` when the *compute* nest is exactly a row-major matmul
    /// (structural check — also true for MLP, whose epilogue differs but
    /// whose accumulation is matmul-shaped). Gates the executor's
    /// microkernel fast path.
    pub fn mm_kernel_shape(&self) -> Option<(usize, usize, usize)> {
        if self.n_dims != 3 {
            return None;
        }
        let (m, n, k) = (self.extent(Dim::M), self.extent(Dim::N), self.extent(Dim::K));
        let a = Access::none().with(Dim::M, k).with(Dim::K, 1);
        let b = Access::none().with(Dim::K, n).with(Dim::N, 1);
        let o = Access::none().with(Dim::M, n).with(Dim::N, 1);
        let reduce_ok =
            !self.is_reduce(Dim::M) && !self.is_reduce(Dim::N) && self.is_reduce(Dim::K);
        let access_ok =
            self.inputs[0].access == a && self.inputs[1].access == b && self.out == o;
        if reduce_ok && access_ok {
            Some((m, n, k))
        } else {
            None
        }
    }

    /// Structural register-tile query over the access maps: can an
    /// innermost `(outer, inner)` loop-level pair dispatch to the
    /// register-tiled microkernels?
    ///
    /// The pattern (the *structure* of a matmul inner pair, with no
    /// reference to any particular constructor) is: one dim is a reduction
    /// `r`, the other an output dim `v` written at unit stride; one input
    /// (the *dot-row* operand) walks `r` contiguously and ignores `v`; the
    /// other (the *row-panel* operand) walks `v` contiguously. Plain and
    /// batched matmul `(k, n)`/`(n, k)`, MLP layers, and conv2d's
    /// `(kw, ow)` spatial pair all match; transposed matmul (strided `A`
    /// rows) and conv1d's `(ic, oc)` (strided `W` columns) do not.
    pub fn pair_roles(&self, outer: Dim, inner: Dim) -> Option<PairRoles> {
        if outer == inner {
            return None;
        }
        let (r, v, red_outer) = if self.is_reduce(outer) && !self.is_reduce(inner) {
            (outer, inner, true)
        } else if !self.is_reduce(outer) && self.is_reduce(inner) {
            (inner, outer, false)
        } else {
            return None;
        };
        if self.out.stride(v) != Some(1) || self.out.indexed(r) {
            return None;
        }
        let [i0, i1] = self.inputs;
        for (a_input, a, b) in [(0, i0.access, i1.access), (1, i1.access, i0.access)] {
            if a.stride(r) == Some(1) && !a.indexed(v) && b.stride(v) == Some(1) {
                return Some(PairRoles {
                    a_input,
                    b_row_stride: b.stride_or_zero(r),
                    red_outer,
                });
            }
        }
        None
    }

    /// Deterministic hash of (kind, extents) — used for per-problem seeds.
    pub fn dim_hash(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        let mut mix = |v: u64| {
            h = (h ^ v).wrapping_mul(0x0000_0100_0000_01b3);
        };
        for b in self.kind.bytes() {
            mix(b as u64);
        }
        for d in self.dims() {
            mix(self.extent(d) as u64);
        }
        h
    }
}

/// Operand roles for dispatching an innermost level pair to the
/// register-tiled microkernels (see [`Problem::pair_roles`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PairRoles {
    /// Index (into [`Problem::inputs`]) of the dot-row operand: unit
    /// stride along the reduction dim, not indexed by the output dim.
    pub a_input: usize,
    /// Stride of the row-panel operand along the reduction dim (`0` when
    /// it is not indexed by it).
    pub b_row_stride: usize,
    /// Whether the reduction dim is the *outer* level of the pair (the
    /// `kn`-order kernel; `false` = `nk` order).
    pub red_outer: bool,
}

impl std::fmt::Display for Problem {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.id())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_strides_are_row_major() {
        let p = Problem::new(4, 8, 16);
        let [a, b] = *p.inputs();
        assert_eq!(a.access.stride(Dim::M), Some(16));
        assert_eq!(a.access.stride(Dim::K), Some(1));
        assert_eq!(a.access.stride(Dim::N), None);
        assert_eq!(b.access.stride(Dim::K), Some(8));
        assert_eq!(b.access.stride(Dim::N), Some(1));
        assert_eq!(p.out_access().stride(Dim::M), Some(8));
        assert_eq!(p.out_access().stride(Dim::N), Some(1));
        assert_eq!(p.out_access().stride(Dim::K), None);
    }

    #[test]
    fn matmul_flops_footprint_lens() {
        let p = Problem::new(64, 64, 64);
        assert_eq!(p.flops(), 2 * 64 * 64 * 64);
        assert_eq!(p.footprint_bytes(), 4 * (64 * 64 * 4) as u64);
        assert_eq!(p.tensor_len(&p.inputs()[0]), 64 * 64);
        assert_eq!(p.out_len(), 64 * 64);
        assert_eq!(p.as_matmul(), Some((64, 64, 64)));
        assert_eq!(p.mm_kernel_shape(), Some((64, 64, 64)));
    }

    #[test]
    fn id_format() {
        assert_eq!(Problem::new(64, 80, 96).id(), "mm_64x80x96");
        assert_eq!(Problem::batched_matmul(2, 64, 80, 96).id(), "bmm_2x64x80x96");
        assert_eq!(Problem::conv2d(28, 28, 3, 3).id(), "conv2d_28x28x3x3");
    }

    #[test]
    fn reduction_dim_sets() {
        let p = Problem::conv2d(28, 28, 3, 3);
        let reds: Vec<&str> = p
            .dims()
            .filter(|&d| p.is_reduce(d))
            .map(|d| p.dim_name(d))
            .collect();
        assert_eq!(reds, ["kh", "kw"]);
        let outs: Vec<&str> = p.output_dims().map(|d| p.dim_name(d)).collect();
        assert_eq!(outs, ["oh", "ow"]);
    }

    #[test]
    fn conv2d_input_covers_halo() {
        // In is (oh+kh-1) x (ow+kw-1): overlapping windows via shared strides.
        let p = Problem::conv2d(28, 26, 3, 5);
        let input = p.inputs()[0];
        assert_eq!(p.tensor_len(&input), (28 + 3 - 1) * (26 + 5 - 1));
        assert_eq!(input.access.stride(Dim::new(0)), input.access.stride(Dim::new(2)));
    }

    #[test]
    fn batched_matmul_layout() {
        let p = Problem::batched_matmul(4, 8, 16, 32);
        let [a, b] = *p.inputs();
        assert_eq!(a.access.stride(Dim::new(0)), Some(8 * 32));
        assert_eq!(b.access.stride(Dim::new(0)), Some(32 * 16));
        assert_eq!(p.out_access().stride(Dim::new(0)), Some(8 * 16));
        assert_eq!(p.tensor_len(&a), 4 * 8 * 32);
        assert_eq!(p.out_len(), 4 * 8 * 16);
        assert_eq!(p.flops(), 2 * 4 * 8 * 16 * 32);
        assert_eq!(p.mm_kernel_shape(), None);
    }

    #[test]
    fn mlp_has_bias_relu_and_matmul_kernel_shape() {
        let p = Problem::mlp(32, 64, 128);
        assert!(p.relu());
        let bias = p.bias().expect("mlp has bias");
        assert_eq!(p.tensor_len(bias), 64);
        assert_eq!(p.as_matmul(), None);
        assert_eq!(p.mm_kernel_shape(), Some((32, 64, 128)));
        assert_eq!(p.writeback_tensors().len(), 3);
    }

    #[test]
    fn transposed_matmul_swaps_a_strides() {
        let p = Problem::matmul_transposed(8, 16, 32);
        let a = p.inputs()[0];
        assert_eq!(a.access.stride(Dim::M), Some(1));
        assert_eq!(a.access.stride(Dim::K), Some(8));
        assert_eq!(p.mm_kernel_shape(), None);
        assert_eq!(p.tensor_len(&a), 8 * 32);
    }

    #[test]
    fn pair_roles_matmul_orders() {
        let p = Problem::new(8, 16, 32);
        // (k, n): reduction outer -> kn order; A is the dot-row operand.
        let kn = p.pair_roles(Dim::K, Dim::N).expect("kn pair");
        assert_eq!(kn, PairRoles { a_input: 0, b_row_stride: 16, red_outer: true });
        // (n, k): vectorizable outer -> nk order.
        let nk = p.pair_roles(Dim::N, Dim::K).expect("nk pair");
        assert!(!nk.red_outer);
        assert_eq!((nk.a_input, nk.b_row_stride), (0, 16));
        // Two output dims, same dim, or (m, k) with strided A: no pair.
        assert_eq!(p.pair_roles(Dim::M, Dim::N), None);
        assert_eq!(p.pair_roles(Dim::K, Dim::K), None);
        assert_eq!(p.pair_roles(Dim::M, Dim::K), None);
    }

    #[test]
    fn pair_roles_generalized_families() {
        // bmm: per-batch matmul structure, same roles as plain matmul.
        let p = Problem::batched_matmul(2, 8, 16, 32);
        let (dn, dk) = (Dim::new(2), Dim::new(3));
        let r = p.pair_roles(dn, dk).expect("bmm nk pair");
        assert_eq!(r, PairRoles { a_input: 0, b_row_stride: 16, red_outer: false });

        // conv2d (kw, ow): W is the dot-row operand, In the row panel with
        // row stride 1 (the overlapping window).
        let p = Problem::conv2d(8, 8, 3, 3);
        let (dow, dkw) = (Dim::new(1), Dim::new(3));
        let r = p.pair_roles(dkw, dow).expect("conv2d kw/ow pair");
        assert_eq!(r, PairRoles { a_input: 1, b_row_stride: 1, red_outer: true });

        // Transposed matmul: A walks k at stride m -> no dot-row operand.
        let p = Problem::matmul_transposed(8, 16, 32);
        assert_eq!(p.pair_roles(Dim::K, Dim::N), None);
        assert_eq!(p.pair_roles(Dim::N, Dim::K), None);

        // conv1d (ic, oc): W's oc stride is kw*ic, not 1 -> no row panel.
        let p = Problem::conv1d(16, 8, 3, 4);
        assert_eq!(p.pair_roles(Dim::new(3), Dim::new(1)), None);
    }

    #[test]
    fn custom_constructor_validates_and_sizes() {
        // Elementwise product: C[i, j] = A[i, j] * B[i, j] (no reduction).
        let (di, dj) = (Dim::new(0), Dim::new(1));
        let a = Access::none().with(di, 6).with(dj, 1);
        let p = Problem::custom(
            "ew",
            &[("i", 4, false), ("j", 6, false)],
            ("A", a),
            ("B", a),
            a,
        );
        assert_eq!(p.out_len(), 24);
        assert_eq!(p.flops(), 2 * 24);
        assert_eq!(p.id(), "ew_4x6");
        assert_eq!(p.mm_kernel_shape(), None);
    }

    #[test]
    #[should_panic(expected = "at least one output dim")]
    fn custom_rejects_all_reduce_problems() {
        let di = Dim::new(0);
        let a = Access::none().with(di, 1);
        Problem::custom("dotp", &[("i", 8, true)], ("A", a), ("B", a), Access::none());
    }

    #[test]
    #[should_panic(expected = "must index the output")]
    fn custom_rejects_unindexed_output_dim() {
        let di = Dim::new(0);
        let a = Access::none().with(di, 1);
        Problem::custom(
            "bad",
            &[("i", 4, false), ("j", 6, false)],
            ("A", a),
            ("B", a),
            a,
        );
    }

    #[test]
    fn epilogue_builders_set_bias_relu_and_suffix_id() {
        let p = Problem::new(8, 16, 32).with_bias(Dim::N).with_relu();
        assert!(p.relu());
        let bias = p.bias().expect("bias attached");
        assert_eq!(bias.access.stride(Dim::N), Some(1));
        assert_eq!(p.tensor_len(bias), 16);
        assert_eq!(p.id(), "mm_8x16x32+bias+relu");
        assert_eq!(Problem::new(8, 16, 32).with_bias(Dim::N).id(), "mm_8x16x32+bias");
        assert_eq!(Problem::new(8, 16, 32).with_relu().id(), "mm_8x16x32+relu");
        // conv2d's unit-stride output dim is ow (dim 1).
        let c = Problem::conv2d(8, 8, 3, 3).with_bias(Dim::new(1));
        assert_eq!(c.id(), "conv2d_8x8x3x3+bias");
        // mlp implies both epilogues; its id stays bare.
        assert_eq!(Problem::mlp(8, 16, 32).id(), "mlp_8x16x32");
    }

    #[test]
    #[should_panic(expected = "unit output stride")]
    fn with_bias_rejects_non_unit_stride_dim() {
        let _ = Problem::new(8, 16, 32).with_bias(Dim::M);
    }

    #[test]
    #[should_panic(expected = "output dim")]
    fn with_bias_rejects_reduction_dim() {
        let _ = Problem::new(8, 16, 32).with_bias(Dim::K);
    }

    #[test]
    fn dim_hash_distinguishes_kind_and_extents() {
        let a = Problem::new(64, 64, 64);
        assert_eq!(a.dim_hash(), Problem::new(64, 64, 64).dim_hash());
        assert_ne!(a.dim_hash(), Problem::new(64, 64, 80).dim_hash());
        assert_ne!(a.dim_hash(), Problem::mlp(64, 64, 64).dim_hash());
        assert_ne!(a.dim_hash(), Problem::matmul_transposed(64, 64, 64).dim_hash());
    }
}
