//! Loop-nest IR — the "LoopTool" substrate (paper §III, Fig 3/4).
//!
//! A [`Nest`] is an ordered list of loops (outermost first), partitioned
//! into a *compute* nest (accumulates `T[m,n] += A[m,k] * B[k,n]`) and a
//! *write-back* nest (copies `T` into `C`). Each dimension (m/n/k) has one
//! **root** loop per nest kind plus zero or more **tile** loops created by
//! `split` actions.
//!
//! Semantics (documented precisely because they drive both the executor
//! and the featurizer):
//!
//! - The *IR stride* of a loop is the number of **elements of its
//!   dimension** advanced per iteration: the product of the tile factors of
//!   all deeper loops of the same dimension in the same nest kind. The
//!   deepest loop of a dimension has stride 1.
//! - A root loop's trip count is `ceil(extent / stride)`; a tile loop's
//!   trip count is its factor (the executor clamps partial chunks at the
//!   extent boundary, exactly like the `min()` bounds of hand-tiled code).
//! - The *tail* of the root is `extent % stride`; the tail of a tile loop
//!   is the leftover its level sees inside the parent's tail region:
//!   `tail(l_i) = tail(l_{i-1}) % stride(l_i)` (paper: the remainder
//!   executed "at the end of the loop nest execution").
//!
//! Invariant maintained by all transforms: within a nest kind, a
//! dimension's root loop precedes all of its tile loops (swaps between two
//! loops of the same dimension are invalid actions, see `env::actions`).

pub mod display;
pub mod problem;
pub mod transform;

pub use problem::{Problem, Tensor};

use crate::util::ceil_div;

/// Maximum number of loops a nest may grow to — bounds the state vector.
pub const MAX_LOOPS: usize = 10;

/// Which nest a loop belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Kind {
    Compute,
    WriteBack,
}

/// A contraction dimension. For matmul: M, N, K.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dim {
    M = 0,
    N = 1,
    K = 2,
}

impl Dim {
    pub const ALL: [Dim; 3] = [Dim::M, Dim::N, Dim::K];

    pub fn name(self) -> &'static str {
        match self {
            Dim::M => "m",
            Dim::N => "n",
            Dim::K => "k",
        }
    }

    pub fn index(self) -> usize {
        self as usize
    }
}

/// One loop level.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Loop {
    pub dim: Dim,
    /// `None` = root loop (covers the remaining extent), `Some(f)` = tile
    /// loop created by `split(f)`.
    pub factor: Option<usize>,
    pub kind: Kind,
}

/// A scheduled loop nest for one contraction problem, plus the agent cursor.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Nest {
    pub problem: Problem,
    /// Outermost first. All `Kind::Compute` loops precede all
    /// `Kind::WriteBack` loops.
    pub loops: Vec<Loop>,
    /// Agent cursor (paper §III-A): index into `loops`.
    pub cursor: usize,
}

impl Nest {
    /// The untiled starting nest: compute `m, n, k`; write-back `m, n`.
    pub fn initial(problem: Problem) -> Self {
        let loops = vec![
            Loop { dim: Dim::M, factor: None, kind: Kind::Compute },
            Loop { dim: Dim::N, factor: None, kind: Kind::Compute },
            Loop { dim: Dim::K, factor: None, kind: Kind::Compute },
            Loop { dim: Dim::M, factor: None, kind: Kind::WriteBack },
            Loop { dim: Dim::N, factor: None, kind: Kind::WriteBack },
        ];
        Nest { problem, loops, cursor: 0 }
    }

    pub fn extent(&self, dim: Dim) -> usize {
        self.problem.extent(dim)
    }

    /// Number of loops in the given nest kind.
    pub fn count_kind(&self, kind: Kind) -> usize {
        self.loops.iter().filter(|l| l.kind == kind).count()
    }

    /// IR stride of loop `idx`: product of tile factors of deeper loops of
    /// the same dim and kind.
    pub fn stride(&self, idx: usize) -> usize {
        let l = self.loops[idx];
        self.loops[idx + 1..]
            .iter()
            .filter(|o| o.dim == l.dim && o.kind == l.kind)
            .map(|o| o.factor.expect("root loop must be outermost for its dim"))
            .product()
    }

    /// Trip count of loop `idx`.
    pub fn trip(&self, idx: usize) -> usize {
        let l = self.loops[idx];
        match l.factor {
            Some(f) => f,
            None => ceil_div(self.extent(l.dim), self.stride(idx)),
        }
    }

    /// Tail (leftover elements at this level) of loop `idx`. See module doc.
    pub fn tail(&self, idx: usize) -> usize {
        let l = self.loops[idx];
        // Walk this dim's loops outer->inner down to idx, cascading the
        // remainder.
        let mut tail = 0usize;
        let mut seen_root = false;
        for (i, o) in self.loops.iter().enumerate() {
            if o.dim != l.dim || o.kind != l.kind {
                continue;
            }
            let stride = self.stride(i);
            if o.factor.is_none() {
                tail = self.extent(l.dim) % stride;
                seen_root = true;
            } else {
                debug_assert!(seen_root, "root must precede tiles");
                tail %= stride;
            }
            if i == idx {
                return tail;
            }
        }
        unreachable!("loop index out of range")
    }

    /// Total iteration volume of the compute nest (product of trips),
    /// counting clamped partial chunks as full — an upper bound used by
    /// validity checks and tests.
    pub fn compute_trip_volume(&self) -> usize {
        self.loops
            .iter()
            .enumerate()
            .filter(|(_, l)| l.kind == Kind::Compute)
            .map(|(i, _)| self.trip(i))
            .product()
    }

    /// Indices of loops in the given kind, outermost first.
    pub fn kind_indices(&self, kind: Kind) -> Vec<usize> {
        self.loops
            .iter()
            .enumerate()
            .filter(|(_, l)| l.kind == kind)
            .map(|(i, _)| i)
            .collect()
    }

    /// Check all structural invariants; used by tests and debug assertions.
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.loops.is_empty() {
            return Err("empty nest".into());
        }
        if self.cursor >= self.loops.len() {
            return Err(format!("cursor {} out of range", self.cursor));
        }
        if self.loops.len() > MAX_LOOPS {
            return Err(format!("{} loops > MAX_LOOPS", self.loops.len()));
        }
        // Compute block precedes write-back block.
        let first_wb = self.loops.iter().position(|l| l.kind == Kind::WriteBack);
        if let Some(fw) = first_wb {
            if self.loops[fw..].iter().any(|l| l.kind == Kind::Compute) {
                return Err("compute loop after write-back loop".into());
            }
        }
        // Per (dim, kind): exactly one root, and it precedes all tiles.
        for kind in [Kind::Compute, Kind::WriteBack] {
            for dim in Dim::ALL {
                let idxs: Vec<usize> = self
                    .loops
                    .iter()
                    .enumerate()
                    .filter(|(_, l)| l.dim == dim && l.kind == kind)
                    .map(|(i, _)| i)
                    .collect();
                if idxs.is_empty() {
                    if kind == Kind::Compute || dim != Dim::K {
                        if !(kind == Kind::WriteBack && dim == Dim::K) {
                            return Err(format!("missing {dim:?} loop in {kind:?}"));
                        }
                    }
                    continue;
                }
                let roots =
                    idxs.iter().filter(|&&i| self.loops[i].factor.is_none()).count();
                if roots != 1 {
                    return Err(format!("{roots} roots for {dim:?} in {kind:?}"));
                }
                if self.loops[idxs[0]].factor.is_some() {
                    return Err(format!("root not outermost for {dim:?} in {kind:?}"));
                }
                for &i in &idxs {
                    if let Some(f) = self.loops[i].factor {
                        if f < 2 {
                            return Err(format!("tile factor {f} < 2"));
                        }
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nest() -> Nest {
        Nest::initial(Problem::new(64, 96, 128))
    }

    #[test]
    fn initial_shape() {
        let n = nest();
        n.check_invariants().unwrap();
        assert_eq!(n.loops.len(), 5);
        assert_eq!(n.count_kind(Kind::Compute), 3);
        assert_eq!(n.count_kind(Kind::WriteBack), 2);
        assert_eq!(n.cursor, 0);
    }

    #[test]
    fn initial_trips_match_extents() {
        let n = nest();
        assert_eq!(n.trip(0), 64); // m
        assert_eq!(n.trip(1), 96); // n
        assert_eq!(n.trip(2), 128); // k
        assert_eq!(n.trip(3), 64); // wb m
        assert_eq!(n.trip(4), 96); // wb n
        for i in 0..5 {
            assert_eq!(n.stride(i), 1);
            assert_eq!(n.tail(i), 0);
        }
    }

    #[test]
    fn stride_after_manual_tile() {
        let mut n = nest();
        // m root, m tile(16), n, k  (hand-built)
        n.loops.insert(
            1,
            Loop { dim: Dim::M, factor: Some(16), kind: Kind::Compute },
        );
        n.check_invariants().unwrap();
        assert_eq!(n.stride(0), 16); // root m advances 16 elements/iter
        assert_eq!(n.trip(0), 4); // ceil(64/16)
        assert_eq!(n.trip(1), 16);
        assert_eq!(n.tail(0), 0);
        assert_eq!(n.tail(1), 0);
    }

    #[test]
    fn tail_with_non_dividing_factor() {
        let mut n = Nest::initial(Problem::new(100, 64, 64));
        n.loops.insert(
            1,
            Loop { dim: Dim::M, factor: Some(48), kind: Kind::Compute },
        );
        assert_eq!(n.trip(0), ceil_div(100, 48)); // 3
        assert_eq!(n.tail(0), 100 % 48); // 4 leftover elements
        assert_eq!(n.tail(1), 4 % 1); // deepest level: 0
    }

    #[test]
    fn invariants_catch_violations() {
        let mut n = nest();
        n.cursor = 99;
        assert!(n.check_invariants().is_err());

        let mut n = nest();
        n.loops[0].factor = Some(8); // root replaced by tile -> no root
        assert!(n.check_invariants().is_err());

        let mut n = nest();
        n.loops.swap(2, 3); // compute k after wb m
        assert!(n.check_invariants().is_err());
    }
}
