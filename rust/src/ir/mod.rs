//! Loop-nest IR — the "LoopTool" substrate (paper §III, Fig 3/4).
//!
//! A [`Nest`] is an ordered list of loops (outermost first), partitioned
//! into a *compute* nest (accumulates `T[out] += In0[..] * In1[..]` over
//! the problem's reduction dims) and a *write-back* nest (applies the
//! problem's epilogue — plain copy, or bias + ReLU — from `T` into `C`).
//! Each iteration dim of the [`Problem`] has one **root** loop per nest
//! kind plus zero or more **tile** loops created by `split` actions; the
//! write-back nest iterates only the output (non-reduction) dims.
//!
//! Semantics (documented precisely because they drive both the executor
//! and the featurizer):
//!
//! - The *IR stride* of a loop is the number of **elements of its
//!   dimension** advanced per iteration: the product of the tile factors of
//!   all deeper loops of the same dimension in the same nest kind. The
//!   deepest loop of a dimension has stride 1.
//! - A root loop's trip count is `ceil(extent / stride)`; a tile loop's
//!   trip count is its factor (the executor clamps partial chunks at the
//!   extent boundary, exactly like the `min()` bounds of hand-tiled code).
//! - The *tail* of the root is `extent % stride`; the tail of a tile loop
//!   is the leftover its level sees inside the parent's tail region:
//!   `tail(l_i) = tail(l_{i-1}) % stride(l_i)` (paper: the remainder
//!   executed "at the end of the loop nest execution").
//!
//! Invariant maintained by all transforms: within a nest kind, a
//! dimension's root loop precedes all of its tile loops (swaps between two
//! loops of the same dimension are invalid actions, see `env::actions`).

pub mod display;
pub mod problem;
pub mod transform;

pub use problem::{Access, Dim, PairRoles, Problem, TensorInfo, TensorList, MAX_DIMS};

use crate::util::ceil_div;

/// Maximum number of loops a nest may grow to — bounds the state vector.
pub const MAX_LOOPS: usize = 10;

/// Which nest a loop belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Kind {
    /// The contraction nest (reads inputs, accumulates into `T`).
    Compute,
    /// The epilogue nest (reads `T` and bias, writes `C`).
    WriteBack,
}

/// One loop level.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Loop {
    /// The iteration dim this loop advances.
    pub dim: Dim,
    /// `None` = root loop (covers the remaining extent), `Some(f)` = tile
    /// loop created by `split(f)`.
    pub factor: Option<usize>,
    /// Which nest the loop belongs to.
    pub kind: Kind,
    /// Marked for chunked multi-thread execution by the `parallelize`
    /// transform. At most one loop per nest carries this flag, it is
    /// always a compute root, and the executor distributes its iterations
    /// across a scoped thread pool with per-chunk privatized accumulators
    /// merged in ascending chunk order (bit-exact for any thread count).
    pub parallel: bool,
}

/// A scheduled loop nest for one contraction problem, plus the agent cursor.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Nest {
    /// The problem this nest schedules.
    pub problem: Problem,
    /// Outermost first. All `Kind::Compute` loops precede all
    /// `Kind::WriteBack` loops.
    pub loops: Vec<Loop>,
    /// Agent cursor (paper §III-A): index into `loops`.
    pub cursor: usize,
}

impl Nest {
    /// The untiled starting nest: one compute root per problem dim (in
    /// declaration order), one write-back root per output dim. For matmul:
    /// compute `m, n, k`; write-back `m, n`.
    pub fn initial(problem: Problem) -> Self {
        let mut loops: Vec<Loop> = problem
            .dims()
            .map(|dim| Loop { dim, factor: None, kind: Kind::Compute, parallel: false })
            .collect();
        loops.extend(
            problem
                .output_dims()
                .map(|dim| Loop { dim, factor: None, kind: Kind::WriteBack, parallel: false }),
        );
        let nest = Nest { problem, loops, cursor: 0 };
        debug_assert!(nest.check_invariants().is_ok());
        nest
    }

    /// Extent of `dim` in this nest's problem.
    pub fn extent(&self, dim: Dim) -> usize {
        self.problem.extent(dim)
    }

    /// Number of loops in the given nest kind.
    pub fn count_kind(&self, kind: Kind) -> usize {
        self.loops.iter().filter(|l| l.kind == kind).count()
    }

    /// IR stride of loop `idx`: product of tile factors of deeper loops of
    /// the same dim and kind.
    pub fn stride(&self, idx: usize) -> usize {
        let l = self.loops[idx];
        self.loops[idx + 1..]
            .iter()
            .filter(|o| o.dim == l.dim && o.kind == l.kind)
            .map(|o| o.factor.expect("root loop must be outermost for its dim"))
            .product()
    }

    /// Trip count of loop `idx`.
    pub fn trip(&self, idx: usize) -> usize {
        let l = self.loops[idx];
        match l.factor {
            Some(f) => f,
            None => ceil_div(self.extent(l.dim), self.stride(idx)),
        }
    }

    /// Tail (leftover elements at this level) of loop `idx`. See module doc.
    pub fn tail(&self, idx: usize) -> usize {
        let l = self.loops[idx];
        // Walk this dim's loops outer->inner down to idx, cascading the
        // remainder.
        let mut tail = 0usize;
        let mut seen_root = false;
        for (i, o) in self.loops.iter().enumerate() {
            if o.dim != l.dim || o.kind != l.kind {
                continue;
            }
            let stride = self.stride(i);
            if o.factor.is_none() {
                tail = self.extent(l.dim) % stride;
                seen_root = true;
            } else {
                debug_assert!(seen_root, "root must precede tiles");
                tail %= stride;
            }
            if i == idx {
                return tail;
            }
        }
        unreachable!("loop index out of range")
    }

    /// Total iteration volume of the compute nest (product of trips),
    /// counting clamped partial chunks as full — an upper bound used by
    /// validity checks and tests.
    pub fn compute_trip_volume(&self) -> usize {
        self.loops
            .iter()
            .enumerate()
            .filter(|(_, l)| l.kind == Kind::Compute)
            .map(|(i, _)| self.trip(i))
            .product()
    }

    /// Indices of loops in the given kind, outermost first.
    pub fn kind_indices(&self, kind: Kind) -> Vec<usize> {
        self.loops
            .iter()
            .enumerate()
            .filter(|(_, l)| l.kind == kind)
            .map(|(i, _)| i)
            .collect()
    }

    /// Check all structural invariants; used by tests and debug assertions.
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.loops.is_empty() {
            return Err("empty nest".into());
        }
        if self.cursor >= self.loops.len() {
            return Err(format!("cursor {} out of range", self.cursor));
        }
        if self.loops.len() > MAX_LOOPS {
            return Err(format!("{} loops > MAX_LOOPS", self.loops.len()));
        }
        // Compute block precedes write-back block.
        let first_wb = self.loops.iter().position(|l| l.kind == Kind::WriteBack);
        if let Some(fw) = first_wb {
            if self.loops[fw..].iter().any(|l| l.kind == Kind::Compute) {
                return Err("compute loop after write-back loop".into());
            }
        }
        // Per (dim, kind): exactly one root, and it precedes all tiles.
        // The compute nest must cover every dim; the write-back nest must
        // cover exactly the output dims.
        for kind in [Kind::Compute, Kind::WriteBack] {
            for dim in self.problem.dims() {
                let name = self.problem.dim_name(dim);
                let idxs: Vec<usize> = self
                    .loops
                    .iter()
                    .enumerate()
                    .filter(|(_, l)| l.dim == dim && l.kind == kind)
                    .map(|(i, _)| i)
                    .collect();
                let required = kind == Kind::Compute || !self.problem.is_reduce(dim);
                if idxs.is_empty() {
                    if required {
                        return Err(format!("missing {name} loop in {kind:?}"));
                    }
                    continue;
                }
                if !required {
                    return Err(format!("reduction dim {name} in {kind:?} nest"));
                }
                let roots =
                    idxs.iter().filter(|&&i| self.loops[i].factor.is_none()).count();
                if roots != 1 {
                    return Err(format!("{roots} roots for {name} in {kind:?}"));
                }
                if self.loops[idxs[0]].factor.is_some() {
                    return Err(format!("root not outermost for {name} in {kind:?}"));
                }
                for &i in &idxs {
                    if let Some(f) = self.loops[i].factor {
                        if f < 2 {
                            return Err(format!("tile factor {f} < 2"));
                        }
                    }
                }
            }
        }
        // Parallel marks: at most one, and only on a compute root. (The
        // "enough deeper loops" check is a parallelize()-time legality rule,
        // not an invariant — later swaps may move loops past the mark.)
        let par: Vec<usize> = self
            .loops
            .iter()
            .enumerate()
            .filter(|(_, l)| l.parallel)
            .map(|(i, _)| i)
            .collect();
        if par.len() > 1 {
            return Err(format!("{} parallel loops (max 1)", par.len()));
        }
        if let Some(&i) = par.first() {
            let l = self.loops[i];
            if l.kind != Kind::Compute {
                return Err("parallel mark on a write-back loop".into());
            }
            if l.factor.is_some() {
                return Err("parallel mark on a tile loop (roots only)".into());
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nest() -> Nest {
        Nest::initial(Problem::new(64, 96, 128))
    }

    #[test]
    fn initial_shape() {
        let n = nest();
        n.check_invariants().unwrap();
        assert_eq!(n.loops.len(), 5);
        assert_eq!(n.count_kind(Kind::Compute), 3);
        assert_eq!(n.count_kind(Kind::WriteBack), 2);
        assert_eq!(n.cursor, 0);
    }

    #[test]
    fn initial_shape_generalized_workloads() {
        // bmm: 4 compute roots + 3 write-back roots.
        let n = Nest::initial(Problem::batched_matmul(4, 64, 64, 64));
        n.check_invariants().unwrap();
        assert_eq!(n.count_kind(Kind::Compute), 4);
        assert_eq!(n.count_kind(Kind::WriteBack), 3);
        assert!(n.loops.len() <= MAX_LOOPS);

        // conv2d: 4 compute roots (oh ow kh kw) + 2 write-back (oh ow).
        let n = Nest::initial(Problem::conv2d(28, 28, 3, 3));
        n.check_invariants().unwrap();
        assert_eq!(n.count_kind(Kind::Compute), 4);
        assert_eq!(n.count_kind(Kind::WriteBack), 2);
        assert_eq!(n.trip(2), 3); // kh root

        // conv1d and mlp also start valid and within the loop bound.
        for p in [Problem::conv1d(64, 32, 5, 16), Problem::mlp(64, 64, 64)] {
            let n = Nest::initial(p);
            n.check_invariants().unwrap();
            assert!(n.loops.len() <= MAX_LOOPS);
        }
    }

    #[test]
    fn initial_trips_match_extents() {
        let n = nest();
        assert_eq!(n.trip(0), 64); // m
        assert_eq!(n.trip(1), 96); // n
        assert_eq!(n.trip(2), 128); // k
        assert_eq!(n.trip(3), 64); // wb m
        assert_eq!(n.trip(4), 96); // wb n
        for i in 0..5 {
            assert_eq!(n.stride(i), 1);
            assert_eq!(n.tail(i), 0);
        }
    }

    #[test]
    fn stride_after_manual_tile() {
        let mut n = nest();
        // m root, m tile(16), n, k  (hand-built)
        n.loops.insert(
            1,
            Loop { dim: Dim::M, factor: Some(16), kind: Kind::Compute, parallel: false },
        );
        n.check_invariants().unwrap();
        assert_eq!(n.stride(0), 16); // root m advances 16 elements/iter
        assert_eq!(n.trip(0), 4); // ceil(64/16)
        assert_eq!(n.trip(1), 16);
        assert_eq!(n.tail(0), 0);
        assert_eq!(n.tail(1), 0);
    }

    #[test]
    fn tail_with_non_dividing_factor() {
        let mut n = Nest::initial(Problem::new(100, 64, 64));
        n.loops.insert(
            1,
            Loop { dim: Dim::M, factor: Some(48), kind: Kind::Compute, parallel: false },
        );
        assert_eq!(n.trip(0), ceil_div(100, 48)); // 3
        assert_eq!(n.tail(0), 100 % 48); // 4 leftover elements
        assert_eq!(n.tail(1), 4 % 1); // deepest level: 0
    }

    /// Satellite: split-tail semantics on non-dividing extents of the
    /// generalized dims (conv spatial dims), pinning the module-doc
    /// invariant `tail(l_i) = tail(l_{i-1}) % stride(l_i)`.
    #[test]
    fn tail_cascade_on_conv_spatial_dims() {
        let p = Problem::conv2d(28, 30, 3, 3);
        let mut n = Nest::initial(p);
        // Split oh (extent 28) by 16, then the 16-tile by 3:
        // oh root (stride 18), oh:6 (stride 3), oh:3 (stride 1).
        n.cursor = 0;
        n.split(16).unwrap();
        n.cursor = 1;
        n.split(3).unwrap();
        assert_eq!(n.loops[1].factor, Some(6)); // ceil(16/3)
        assert_eq!(n.stride(0), 18);
        assert_eq!(n.tail(0), 28 % 18); // 10
        assert_eq!(n.tail(1), 10 % 3); // 1
        assert_eq!(n.tail(2), 1 % 1); // 0
        n.check_invariants().unwrap();
    }

    /// Property over all workload families: every loop's tail equals the
    /// parent tail modulo its own stride, after random transform chains.
    #[test]
    fn prop_tail_cascade_all_workloads() {
        use crate::util::rng::Pcg32;
        let problems = [
            Problem::new(100, 96, 64),
            Problem::batched_matmul(3, 50, 64, 48),
            Problem::conv1d(75, 24, 5, 12),
            Problem::conv2d(27, 29, 3, 5),
            Problem::mlp(90, 70, 110),
        ];
        for (pi, &p) in problems.iter().enumerate() {
            let mut rng = Pcg32::new(0x7a11 + pi as u64);
            let mut n = Nest::initial(p);
            for _ in 0..50 {
                match rng.below(5) {
                    0 => drop(n.cursor_up()),
                    1 => drop(n.cursor_down()),
                    2 => drop(n.swap_up()),
                    3 => drop(n.swap_down()),
                    _ => drop(n.split(*rng.choose(&[2usize, 3, 4, 7, 16]))),
                }
                n.check_invariants().unwrap_or_else(|e| panic!("{p}: {e}"));
                // Cascade check per (dim, kind) chain, outer to inner.
                for kind in [Kind::Compute, Kind::WriteBack] {
                    for dim in p.dims() {
                        let chain: Vec<usize> = n
                            .loops
                            .iter()
                            .enumerate()
                            .filter(|(_, l)| l.dim == dim && l.kind == kind)
                            .map(|(i, _)| i)
                            .collect();
                        for w in chain.windows(2) {
                            let expect = n.tail(w[0]) % n.stride(w[1]);
                            assert_eq!(n.tail(w[1]), expect, "{p}: loops {w:?}");
                        }
                        if let Some(&root) = chain.first() {
                            assert_eq!(n.tail(root), p.extent(dim) % n.stride(root));
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn invariants_catch_violations() {
        let mut n = nest();
        n.cursor = 99;
        assert!(n.check_invariants().is_err());

        let mut n = nest();
        n.loops[0].factor = Some(8); // root replaced by tile -> no root
        assert!(n.check_invariants().is_err());

        let mut n = nest();
        n.loops.swap(2, 3); // compute k after wb m
        assert!(n.check_invariants().is_err());

        // Reduction dim in the write-back nest is invalid.
        let mut n = nest();
        n.loops.push(Loop { dim: Dim::K, factor: None, kind: Kind::WriteBack, parallel: false });
        assert!(n.check_invariants().is_err());
    }
}
