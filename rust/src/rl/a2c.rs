//! A2C and IMPALA trainers.
//!
//! A2C is the synchronous form of A3C (identical gradient estimator; the
//! async worker parallelism of A3C is meaningless on one core). IMPALA
//! reuses the same compiled `a2c_train_step` but collects rollouts under a
//! **stale behavior policy** (synced every `behavior_sync` iterations) and
//! corrects the targets with V-trace (Espeholt et al. 2018), computed by
//! the coordinator from current-policy log-probs and values.

use super::params::ParamSet;
use super::ppo::{pv_with_lits, RolloutStep};
use super::{IterStats, TrainLog};
use crate::backend::SharedBackend;
use crate::env::actions::Action;
use crate::env::Env;
use crate::ir::Problem;
use crate::runtime::literal::{lit_f32, lit_f32_scalar, lit_i32, scalar_f32, HostTensor};
use crate::runtime::{xla, Runtime};
use crate::util::rng::Pcg32;
use crate::STATE_DIM;
use anyhow::Result;
use std::sync::Arc;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct A2cConfig {
    pub gamma: f32,
    pub lr: f32,
    pub ent_coef: f32,
    pub episode_len: usize,
    pub episodes_per_iter: usize,
    /// IMPALA mode: V-trace correction + stale behavior policy.
    pub vtrace: bool,
    /// Iterations between behavior-policy syncs (IMPALA actor lag).
    pub behavior_sync: usize,
    /// V-trace clipping (rho_bar = c_bar = 1.0 per the paper).
    pub rho_clip: f32,
    pub seed: u64,
}

impl A2cConfig {
    pub fn a2c() -> Self {
        A2cConfig {
            gamma: 0.9,
            lr: 3e-4,
            ent_coef: 0.01,
            episode_len: 10,
            episodes_per_iter: 6,
            vtrace: false,
            behavior_sync: 1,
            rho_clip: 1.0,
            seed: 1,
        }
    }

    pub fn impala() -> Self {
        A2cConfig { vtrace: true, behavior_sync: 4, ..Self::a2c() }
    }
}

/// V-trace targets for one episode: returns (advantages, value targets).
///
/// `rhos[t] = min(rho_clip, pi(a_t|s_t) / mu(a_t|s_t))`; terminal bootstrap
/// is zero (episodes are fixed-length and rewards are deltas).
pub fn vtrace(
    rewards: &[f32],
    values: &[f32],
    rhos: &[f32],
    gamma: f32,
    rho_clip: f32,
) -> (Vec<f32>, Vec<f32>) {
    let n = rewards.len();
    assert_eq!(values.len(), n);
    assert_eq!(rhos.len(), n);
    let clip = |r: f32| r.min(rho_clip);
    // vs_t = V_t + sum_{k>=t} gamma^{k-t} (prod_{j<k} c_j) rho_k delta_k
    // computed with the standard backward recursion.
    let mut vs = vec![0.0f32; n];
    let mut next_vs = 0.0f32; // bootstrap V(s_T) = 0
    let mut next_v = 0.0f32;
    for t in (0..n).rev() {
        let rho = clip(rhos[t]);
        let c = clip(rhos[t]); // c_bar == rho_bar
        let delta = rho * (rewards[t] + gamma * next_v - values[t]);
        vs[t] = values[t] + delta + gamma * c * (next_vs - next_v);
        next_vs = vs[t];
        next_v = values[t];
    }
    // Advantage: rho_t (r_t + gamma vs_{t+1} - V_t)
    let mut adv = vec![0.0f32; n];
    for t in 0..n {
        let next = if t + 1 < n { vs[t + 1] } else { 0.0 };
        adv[t] = clip(rhos[t]) * (rewards[t] + gamma * next - values[t]);
    }
    (adv, vs)
}

pub struct A2cTrainer {
    rt: Arc<Runtime>,
    pub cfg: A2cConfig,
    pub params: ParamSet,
    adam_step: f32,
    rng: Pcg32,
    // SPerf: params/optimizer state cached as Literals between PJRT calls;
    // `behavior_lits` is the stale actor copy (IMPALA) and equals the
    // online params in plain A2C.
    params_lits: Vec<xla::Literal>,
    behavior_lits: Vec<xla::Literal>,
    m_lits: Vec<xla::Literal>,
    v_lits: Vec<xla::Literal>,
}

impl A2cTrainer {
    pub fn new(rt: Arc<Runtime>, cfg: A2cConfig) -> Result<Self> {
        let params = ParamSet::init(&rt, "pv_init", cfg.seed as i32)?;
        let params_lits = params.to_literals()?;
        let behavior_lits = params.to_literals()?;
        let m_lits = params.zeros_like().to_literals()?;
        let v_lits = params.zeros_like().to_literals()?;
        let rng = Pcg32::new(cfg.seed ^ 0xa2c_000);
        Ok(A2cTrainer {
            rt, cfg, params, adam_step: 0.0, rng,
            params_lits, behavior_lits, m_lits, v_lits,
        })
    }

    fn collect_episode(&mut self, env: &mut Env) -> Result<(Vec<RolloutStep>, f32)> {
        let mut steps = Vec::with_capacity(self.cfg.episode_len);
        let mut state = env.state();
        let mut total = 0.0f32;
        for _ in 0..self.cfg.episode_len {
            let (logits, value) = pv_with_lits(&self.rt, &self.behavior_lits, &state)?;
            let a = super::sample_categorical(&logits, &mut self.rng);
            let logp = super::log_softmax(&logits)[a];
            let action = Action::from_index(a)
                .ok_or_else(|| anyhow::anyhow!("action index {a} out of range"))?;
            let st = env.step(action);
            total += st.reward;
            steps.push(RolloutStep {
                state: std::mem::take(&mut state),
                action: a,
                reward: st.reward,
                logp, // behavior-policy logp (mu)
                value, // behavior value; replaced for V-trace below
            });
            state = st.state;
        }
        Ok((steps, total))
    }

    fn update_batch(
        &mut self,
        steps: &[RolloutStep],
        adv: &[f32],
        ret: &[f32],
        batch_idx: &[usize],
    ) -> Result<(f32, f32)> {
        let b = self.rt.constants.batch;
        assert_eq!(batch_idx.len(), b);
        let mut s = Vec::with_capacity(b * STATE_DIM);
        let mut a = Vec::with_capacity(b);
        let mut ad = Vec::with_capacity(b);
        let mut rt_ = Vec::with_capacity(b);
        for &i in batch_idx {
            s.extend_from_slice(&steps[i].state);
            a.push(steps[i].action as i32);
            ad.push(adv[i]);
            rt_.push(ret[i]);
        }
        let tail = [
            lit_f32_scalar(self.adam_step)?,
            lit_f32(&s, &[b, STATE_DIM])?,
            lit_i32(&a, &[b])?,
            lit_f32(&ad, &[b])?,
            lit_f32(&rt_, &[b])?,
            lit_f32_scalar(self.cfg.lr)?,
            lit_f32_scalar(self.cfg.ent_coef)?,
        ];
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(31);
        args.extend(self.params_lits.iter());
        args.extend(self.m_lits.iter());
        args.extend(self.v_lits.iter());
        args.extend(tail.iter());

        let mut outs = self.rt.exec("a2c_train_step", &args)?;
        self.adam_step = scalar_f32(&outs[24])?;
        let loss = scalar_f32(&outs[25])?;
        let ent = scalar_f32(&outs[26])?;
        let mut it = outs.drain(0..24);
        for i in 0..8 {
            self.params_lits[i] = it.next().unwrap();
            self.params.tensors[i] = HostTensor::from_literal(&self.params_lits[i])?;
        }
        for i in 0..8 {
            self.m_lits[i] = it.next().unwrap();
        }
        for i in 0..8 {
            self.v_lits[i] = it.next().unwrap();
        }
        drop(it);
        Ok((loss, ent))
    }

    pub fn train(
        &mut self,
        backend: SharedBackend,
        problems: &[Problem],
        peak: f64,
        iters: usize,
        mut on_iter: impl FnMut(&IterStats),
    ) -> Result<TrainLog> {
        let algo = if self.cfg.vtrace { "impala" } else { "a3c" };
        let mut log = TrainLog { algo: algo.into(), iters: Vec::new() };
        let mut env = Env::new(problems[0], backend, peak);
        let t0 = Instant::now();
        let mut env_steps = 0u64;
        let b = self.rt.constants.batch;

        for iter in 0..iters {
            if !self.cfg.vtrace || iter % self.cfg.behavior_sync == 0 {
                self.behavior_lits = self.params.to_literals()?;
            }
            let mut steps: Vec<RolloutStep> = Vec::new();
            let mut adv: Vec<f32> = Vec::new();
            let mut ret: Vec<f32> = Vec::new();
            let mut rewards = Vec::new();

            for _ in 0..self.cfg.episodes_per_iter {
                let p = *self.rng.choose(problems);
                env.reset(p);
                let (ep, total) = self.collect_episode(&mut env)?;
                env_steps += ep.len() as u64;
                rewards.push(total as f64);

                if self.cfg.vtrace {
                    // Recompute values + current-policy logps; V-trace.
                    let mut values = Vec::with_capacity(ep.len());
                    let mut rhos = Vec::with_capacity(ep.len());
                    for st in &ep {
                        let (logits, value) =
                            pv_with_lits(&self.rt, &self.params_lits, &st.state)?;
                        let logp_cur = super::log_softmax(&logits)[st.action];
                        rhos.push((logp_cur - st.logp).exp());
                        values.push(value);
                    }
                    let rs: Vec<f32> = ep.iter().map(|s| s.reward).collect();
                    let (ea, evs) =
                        vtrace(&rs, &values, &rhos, self.cfg.gamma, self.cfg.rho_clip);
                    adv.extend(ea);
                    ret.extend(evs);
                } else {
                    // Plain A2C: discounted returns, adv = ret - V.
                    let mut g = 0.0f32;
                    let mut er: Vec<f32> = vec![0.0; ep.len()];
                    for t in (0..ep.len()).rev() {
                        g = ep[t].reward + self.cfg.gamma * g;
                        er[t] = g;
                    }
                    for (t, st) in ep.iter().enumerate() {
                        adv.push(er[t] - st.value);
                    }
                    ret.extend(er);
                }
                steps.extend(ep);
            }
            super::ppo::normalize(&mut adv);

            // One pass over the rollout in batches of `b`.
            let mut idx: Vec<usize> = (0..steps.len()).collect();
            self.rng.shuffle(&mut idx);
            let (mut loss_s, mut ent_s, mut nb) = (0.0f64, 0.0f64, 0usize);
            for chunk in idx.chunks(b) {
                let mut batch: Vec<usize> = chunk.to_vec();
                while batch.len() < b {
                    batch.push(idx[self.rng.below(idx.len())]);
                }
                let (l, e) = self.update_batch(&steps, &adv, &ret, &batch)?;
                loss_s += l as f64;
                ent_s += e as f64;
                nb += 1;
            }

            let stats = IterStats {
                iter,
                episode_reward_mean: crate::util::stats::mean(&rewards),
                loss: loss_s / nb.max(1) as f64,
                exploration: ent_s / nb.max(1) as f64,
                env_steps,
                wall_secs: t0.elapsed().as_secs_f64(),
            };
            on_iter(&stats);
            log.iters.push(stats);
        }
        Ok(log)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vtrace_on_policy_reduces_to_td_targets() {
        // rho = 1 everywhere: vs_t = V_t + sum gamma^k delta_k, which for
        // gamma terms telescopes to the discounted-reward targets.
        let rewards = [1.0f32, 1.0, 1.0];
        let values = [0.0f32, 0.0, 0.0];
        let rhos = [1.0f32, 1.0, 1.0];
        let (adv, vs) = vtrace(&rewards, &values, &rhos, 1.0, 1.0);
        // With V = 0 and gamma = 1: vs_t = total future reward.
        assert_eq!(vs, vec![3.0, 2.0, 1.0]);
        assert_eq!(adv, vec![3.0, 2.0, 1.0]);
    }

    #[test]
    fn vtrace_clips_large_ratios() {
        let rewards = [1.0f32, 1.0];
        let values = [0.5f32, 0.5];
        let huge = [10.0f32, 10.0]; // wildly off-policy
        let one = [1.0f32, 1.0];
        let (a_h, _) = vtrace(&rewards, &values, &huge, 0.9, 1.0);
        let (a_1, _) = vtrace(&rewards, &values, &one, 0.9, 1.0);
        // Clipped at rho_bar=1: identical to the on-policy result.
        assert_eq!(a_h, a_1);
    }

    #[test]
    fn vtrace_zero_rho_trusts_value_function() {
        let rewards = [5.0f32];
        let values = [2.0f32];
        let rhos = [0.0f32];
        let (adv, vs) = vtrace(&rewards, &values, &rhos, 0.9, 1.0);
        assert_eq!(adv, vec![0.0]); // no correction possible
        assert_eq!(vs, vec![2.0]); // falls back to V
    }
}
