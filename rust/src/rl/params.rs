//! Network parameter sets: initialization through the AOT `*_init`
//! artifacts, marshalling to/from Literals, and a small binary on-disk
//! format so trained policies can be saved and re-loaded without Python.

use crate::runtime::literal::HostTensor;
use crate::runtime::{xla, Runtime};
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

/// A named, ordered set of tensors (network params, Adam m/v, ...).
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSet {
    pub tensors: Vec<HostTensor>,
}

impl ParamSet {
    pub fn new(tensors: Vec<HostTensor>) -> Self {
        ParamSet { tensors }
    }

    /// Zeroed clone (Adam moment buffers).
    pub fn zeros_like(&self) -> Self {
        ParamSet {
            tensors: self
                .tensors
                .iter()
                .map(|t| HostTensor::zeros(t.shape.clone()))
                .collect(),
        }
    }

    /// Initialize from an AOT initializer entry (`q_init` / `pv_init`).
    pub fn init(rt: &Runtime, entry: &str, seed: i32) -> Result<Self> {
        let outs = rt.exec(
            entry,
            &[crate::runtime::literal::lit_i32_scalar(seed)?],
        )?;
        let tensors = outs
            .iter()
            .map(HostTensor::from_literal)
            .collect::<Result<Vec<_>>>()?;
        Ok(ParamSet { tensors })
    }

    pub fn to_literals(&self) -> Result<Vec<xla::Literal>> {
        self.tensors.iter().map(|t| t.to_literal()).collect()
    }

    pub fn num_params(&self) -> usize {
        self.tensors.iter().map(|t| t.data.len()).sum()
    }

    /// L2 norm over all tensors (training diagnostics).
    pub fn norm(&self) -> f64 {
        self.tensors
            .iter()
            .flat_map(|t| t.data.iter())
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt()
    }

    /// The one policy-loading rule every entry point shares (CLI eval
    /// experiments and the tuning service): load `path` if it names an
    /// existing file, else fall back to a fresh `q_init` at `seed` —
    /// returning whether the result is a *trained* checkpoint — warning
    /// on a named-but-missing path. Loaded checkpoints are contract-checked
    /// (see [`Self::validate_contract`]) so a stale artifact fails with a
    /// descriptive error instead of a shape panic deep in the runtime.
    pub fn load_or_init(
        rt: &Runtime,
        path: Option<&Path>,
        seed: i32,
    ) -> Result<(ParamSet, bool)> {
        if let Some(p) = path {
            if p.exists() {
                return Ok((ParamSet::load_validated(p)?, true));
            }
            eprintln!("warning: params {p:?} not found; using untrained policy");
        }
        Ok((ParamSet::init(rt, "q_init", seed)?, false))
    }

    /// [`Self::load`] followed by [`Self::validate_contract`], naming the
    /// file in any error.
    pub fn load_validated(path: impl AsRef<Path>) -> Result<Self> {
        let p = ParamSet::load(path.as_ref())?;
        p.validate_contract()
            .with_context(|| format!("loading {:?}", path.as_ref()))?;
        Ok(p)
    }

    /// Check this parameter set against the crate's current network
    /// contract: the first matrix must consume `STATE_DIM` features and
    /// the last tensor's trailing dim must equal `NUM_ACTIONS` (the
    /// network head the argmax indexes). Checkpoints saved under an older
    /// contract — e.g. the 10-action head from before `parallelize` was
    /// added — are rejected here with a migration hint instead of
    /// panicking on a shape mismatch inside the compiled executable.
    pub fn validate_contract(&self) -> Result<()> {
        if self.tensors.is_empty() {
            bail!("empty parameter set");
        }
        if let Some(t) = self.tensors.iter().find(|t| t.shape.len() == 2) {
            if t.shape[0] != crate::STATE_DIM {
                bail!(
                    "parameter contract mismatch: first weight matrix consumes \
                     {} features, this build expects STATE_DIM = {} \
                     (checkpoint from an incompatible contract version; retrain \
                     or regenerate it)",
                    t.shape[0],
                    crate::STATE_DIM
                );
            }
        }
        let head = self.tensors.last().expect("non-empty");
        let width = head.shape.last().copied().unwrap_or(0);
        if width != crate::NUM_ACTIONS {
            bail!(
                "parameter contract mismatch: network head is {} actions wide, \
                 this build expects NUM_ACTIONS = {} (contract v2 appended \
                 `parallelize` at index 10; checkpoints from the 10-action \
                 contract must be retrained)",
                width,
                crate::NUM_ACTIONS
            );
        }
        Ok(())
    }

    // ---- binary save/load: "LTPS" magic, version, tensor table ----

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(b"LTPS");
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for t in &self.tensors {
            buf.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
            for &d in &t.shape {
                buf.extend_from_slice(&(d as u64).to_le_bytes());
            }
            for &x in &t.data {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        let mut f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("creating {:?}", path.as_ref()))?;
        f.write_all(&buf)?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let mut bytes = Vec::new();
        std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening {:?}", path.as_ref()))?
            .read_to_end(&mut bytes)?;
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if *pos + n > bytes.len() {
                bail!("truncated param file");
            }
            let s = &bytes[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        if take(&mut pos, 4)? != b"LTPS" {
            bail!("bad magic (not a looptune param file)");
        }
        let ver = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
        if ver != 1 {
            bail!("unsupported param file version {ver}");
        }
        let count = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        let mut tensors = Vec::with_capacity(count);
        for _ in 0..count {
            let ndim =
                u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(
                    u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap()) as usize,
                );
            }
            let n: usize = shape.iter().product();
            let raw = take(&mut pos, 4 * n)?;
            let data = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            tensors.push(HostTensor::new(shape, data));
        }
        if pos != bytes.len() {
            bail!("trailing bytes in param file");
        }
        Ok(ParamSet { tensors })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ParamSet {
        ParamSet::new(vec![
            HostTensor::new(vec![2, 3], vec![1.0, -2.0, 3.5, 0.0, 1e-8, -7.25]),
            HostTensor::new(vec![3], vec![0.5, 0.25, -0.125]),
            HostTensor::scalar(42.0),
        ])
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("ltps_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.ltps");
        let p = sample();
        p.save(&path).unwrap();
        let q = ParamSet::load(&path).unwrap();
        assert_eq!(p, q);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("ltps_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ltps");
        std::fs::write(&path, b"not a param file").unwrap();
        assert!(ParamSet::load(&path).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn zeros_like_and_norm() {
        let p = sample();
        let z = p.zeros_like();
        assert_eq!(z.num_params(), p.num_params());
        assert_eq!(z.norm(), 0.0);
        assert!(p.norm() > 0.0);
    }
}
