//! Policy inference — the paper's headline capability: auto-tune a loop
//! nest **in about a second** by rolling the trained policy forward
//! without any backend evaluation in the loop.
//!
//! The agent applies `argmax Q(s, ·)` for a fixed number of steps, with
//! the paper's implicit stop: "when the agent starts oscillating between
//! states that differ only by the cursor position" — detected here as a
//! revisit of an already-seen (schedule, cursor) state.
//!
//! The service API drives this through [`crate::api::PolicyRollout`];
//! [`tune_masked`] additionally zeroes feature groups in the state vector
//! (the ablation studies' [`FeatureMask`]) — the default mask reproduces
//! [`tune`] bit for bit.

use super::params::ParamSet;
use crate::backend::SharedBackend;
use crate::env::actions::Action;
use crate::featurize::FeatureMask;
use crate::ir::{Nest, Problem};
use crate::runtime::Runtime;
use std::collections::HashSet;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct TuneOutcome {
    pub nest: Nest,
    pub actions: Vec<Action>,
    /// Pure policy-inference time (no backend evaluation) — the paper's
    /// "search time".
    pub infer_secs: f64,
    /// GFLOPS of the produced schedule, measured afterwards by `backend`.
    pub gflops: f64,
    pub initial_gflops: f64,
    pub stopped_early: bool,
    /// Backend evaluations this tune performed (cache misses: at most the
    /// initial and final schedule scores).
    pub evals: u64,
    /// Scores served from the shared cache instead.
    pub cache_hits: u64,
}

impl TuneOutcome {
    pub fn speedup(&self) -> f64 {
        self.gflops / self.initial_gflops.max(1e-12)
    }
}

/// Roll the greedy policy for at most `steps` actions, then score the final
/// schedule with `backend`.
pub fn tune(
    rt: &Runtime,
    params: &ParamSet,
    problem: Problem,
    steps: usize,
    backend: &SharedBackend,
) -> anyhow::Result<TuneOutcome> {
    tune_masked(rt, params, problem, steps, backend, FeatureMask::default())
}

/// [`tune`] with ablation feature groups zeroed in every state vector.
pub fn tune_masked(
    rt: &Runtime,
    params: &ParamSet,
    problem: Problem,
    steps: usize,
    backend: &SharedBackend,
    mask: FeatureMask,
) -> anyhow::Result<TuneOutcome> {
    let t0 = Instant::now();
    let mut nest = Nest::initial(problem);
    let mut actions = Vec::new();
    let mut seen: HashSet<(Vec<crate::ir::Loop>, usize)> = HashSet::new();
    seen.insert((nest.loops.clone(), nest.cursor));
    let mut stopped_early = false;

    for _ in 0..steps {
        let mut state = crate::featurize::state_vector(&nest);
        mask.apply(&mut state);
        let q = super::dqn::q_values_with(rt, params, &state)?;
        // Greedy over valid actions: try best-ranked first. Legality *is*
        // the mask — an action whose `apply` errs (cursor at a boundary,
        // split factor too large, `parallelize` on an illegal loop or a
        // nest that already has a mark) is skipped, never taken.
        let mut order: Vec<usize> = (0..q.len()).collect();
        order.sort_by(|&a, &b| q[b].partial_cmp(&q[a]).unwrap());
        let mut applied = None;
        for idx in order {
            // Skip indices past the action table (stale/oversized artifact).
            let Some(action) = Action::from_index(idx) else { continue };
            let mut next = nest.clone();
            if action.apply(&mut next).is_ok() {
                applied = Some((action, next));
                break;
            }
        }
        let (action, next) = applied.expect("some action is always valid");
        // Implicit stop on state revisit (cursor oscillation).
        if !seen.insert((next.loops.clone(), next.cursor)) {
            stopped_early = true;
            break;
        }
        actions.push(action);
        nest = next;
    }
    let infer_secs = t0.elapsed().as_secs_f64();

    let (initial_gflops, m0) = backend.eval_detail(&Nest::initial(problem));
    let (gflops, m1) = backend.eval_detail(&nest);
    Ok(TuneOutcome {
        nest,
        actions,
        infer_secs,
        gflops,
        initial_gflops,
        stopped_early,
        evals: m0 as u64 + m1 as u64,
        cache_hits: !m0 as u64 + !m1 as u64,
    })
}
