//! DQN and APEX_DQN trainers.
//!
//! Both drive the AOT-compiled `dqn_train_step` (double-DQN + Huber + Adam,
//! lowered from JAX/Pallas). The difference is exactly the paper's:
//!
//! - **DQN**: one actor, uniform replay.
//! - **APEX_DQN**: several (logical) actors with per-actor exploration
//!   rates feeding one *prioritized* replay buffer; the learner samples by
//!   priority and writes |TD| back after every step (Horgan et al. 2018).
//!   On this 1-core testbed the actors interleave round-robin — the data
//!   distribution matches the distributed original, only the wall-clock
//!   parallelism is serialized.

use super::params::ParamSet;
use super::replay::{PrioritizedReplay, Transition, UniformReplay};
use super::{IterStats, TrainLog};
use crate::backend::SharedBackend;
use crate::env::actions::Action;
use crate::env::Env;
use crate::ir::Problem;
use crate::runtime::literal::{lit_f32, lit_f32_scalar, lit_i32, scalar_f32, HostTensor};
use crate::runtime::{xla, Runtime};
use crate::util::rng::Pcg32;
use crate::{NUM_ACTIONS, STATE_DIM};
use anyhow::Result;
use std::sync::Arc;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct DqnConfig {
    pub gamma: f32,
    pub lr: f32,
    pub eps_start: f64,
    pub eps_end: f64,
    /// Iterations over which epsilon anneals linearly.
    pub eps_decay_iters: usize,
    /// Learner steps between target-network syncs.
    pub target_sync: usize,
    pub replay_cap: usize,
    /// Minimum buffered transitions before learning starts.
    pub learn_start: usize,
    /// Episode length (paper: 10 actions per episode).
    pub episode_len: usize,
    /// Episodes collected per iteration (across all actors).
    pub episodes_per_iter: usize,
    /// Learner batches per iteration.
    pub learner_steps: usize,
    /// APEX: prioritized replay + multiple actors.
    pub prioritized: bool,
    pub n_actors: usize,
    pub alpha: f64,
    pub beta: f64,
    pub seed: u64,
    /// Feature-group mask for ablation studies (default: all features).
    pub feature_mask: crate::featurize::FeatureMask,
}

impl DqnConfig {
    pub fn dqn() -> Self {
        DqnConfig {
            gamma: 0.9,
            lr: 5e-4,
            eps_start: 1.0,
            eps_end: 0.05,
            eps_decay_iters: 120,
            target_sync: 40,
            replay_cap: 20_000,
            learn_start: 128,
            episode_len: 10,
            episodes_per_iter: 4,
            learner_steps: 2,
            prioritized: false,
            n_actors: 1,
            alpha: 0.6,
            beta: 0.4,
            seed: 1,
            feature_mask: crate::featurize::FeatureMask::default(),
        }
    }

    pub fn apex() -> Self {
        DqnConfig {
            prioritized: true,
            n_actors: 4,
            learner_steps: 8,
            ..Self::dqn()
        }
    }
}

/// Replay storage behind one interface.
enum Replay {
    Uniform(UniformReplay),
    Prioritized(PrioritizedReplay),
}

pub struct DqnTrainer {
    rt: Arc<Runtime>,
    pub cfg: DqnConfig,
    /// Host copy of the online params (kept in sync for save()/inspection).
    pub params: ParamSet,
    adam_step: f32,
    replay: Replay,
    rng: Pcg32,
    learner_steps_done: usize,
    // §Perf: the network/optimizer state lives as cached Literals between
    // PJRT calls; only the batch arrays are marshalled per learner step,
    // and nothing is marshalled per actor step (EXPERIMENTS.md §Perf).
    params_lits: Vec<xla::Literal>,
    target_lits: Vec<xla::Literal>,
    m_lits: Vec<xla::Literal>,
    v_lits: Vec<xla::Literal>,
}

impl DqnTrainer {
    pub fn new(rt: Arc<Runtime>, cfg: DqnConfig) -> Result<Self> {
        let params = ParamSet::init(&rt, "q_init", cfg.seed as i32)?;
        let params_lits = params.to_literals()?;
        let target_lits = params.to_literals()?;
        let m_lits = params.zeros_like().to_literals()?;
        let v_lits = params.zeros_like().to_literals()?;
        let replay = if cfg.prioritized {
            Replay::Prioritized(PrioritizedReplay::new(cfg.replay_cap, cfg.alpha))
        } else {
            Replay::Uniform(UniformReplay::new(cfg.replay_cap))
        };
        let rng = Pcg32::new(cfg.seed ^ 0xd9_0000);
        Ok(DqnTrainer {
            rt,
            cfg,
            params,
            adam_step: 0.0,
            replay,
            rng,
            learner_steps_done: 0,
            params_lits,
            target_lits,
            m_lits,
            v_lits,
        })
    }

    /// Q(s, ·) through the compiled network (batch-1 artifact), using the
    /// cached param Literals (no per-step marshalling).
    pub fn q_values(&self, state: &[f32]) -> Result<Vec<f32>> {
        let state_lit = lit_f32(state, &[1, STATE_DIM])?;
        let mut args: Vec<&xla::Literal> = self.params_lits.iter().collect();
        args.push(&state_lit);
        let outs = self.rt.exec("q_forward_b1", &args)?;
        Ok(outs[0].to_vec()?)
    }

    fn replay_len(&self) -> usize {
        match &self.replay {
            Replay::Uniform(b) => b.len(),
            Replay::Prioritized(b) => b.len(),
        }
    }

    /// Epsilon for global iteration `iter` and actor `actor`.
    fn epsilon(&self, iter: usize, actor: usize) -> f64 {
        let t = (iter as f64 / self.cfg.eps_decay_iters as f64).min(1.0);
        let base = self.cfg.eps_start + t * (self.cfg.eps_end - self.cfg.eps_start);
        if self.cfg.n_actors <= 1 {
            base
        } else {
            // APEX-style per-actor exploration spread: actor 0 greediest.
            let f = (actor as f64 + 1.0) / self.cfg.n_actors as f64;
            (base * (0.5 + f)).min(1.0)
        }
    }

    /// Run one ε-greedy episode on `env`; returns total reward.
    fn run_episode(&mut self, env: &mut Env, eps: f64) -> Result<f32> {
        let mut state = env.state();
        let mut total = 0.0f32;
        for _ in 0..self.cfg.episode_len {
            let a_idx = if self.rng.next_f64() < eps {
                self.rng.below(NUM_ACTIONS)
            } else {
                super::argmax(&self.q_values(&state)?)
            };
            let action = Action::from_index(a_idx)
                .ok_or_else(|| anyhow::anyhow!("action index {a_idx} out of range"))?;
            let step = env.step(action);
            total += step.reward;
            let done = env.steps >= self.cfg.episode_len;
            let t = Transition {
                state: std::mem::take(&mut state),
                action: a_idx,
                reward: step.reward,
                next_state: step.state.clone(),
                done,
            };
            match &mut self.replay {
                Replay::Uniform(b) => b.push(t),
                Replay::Prioritized(b) => b.push(t),
            }
            state = step.state;
        }
        Ok(total)
    }

    /// One learner batch through the compiled `dqn_train_step`.
    /// Returns the loss.
    pub fn learn(&mut self) -> Result<f32> {
        let batch = self.rt.constants.batch;
        // Sample.
        let (idx, items, weights): (Vec<usize>, Vec<&Transition>, Vec<f32>) =
            match &self.replay {
                Replay::Uniform(b) => {
                    let (i, it) = b.sample(batch, &mut self.rng);
                    (i, it, vec![1.0; batch])
                }
                Replay::Prioritized(b) => {
                    b.sample(batch, self.cfg.beta, &mut self.rng)
                }
            };

        // Flatten the batch.
        let mut s = Vec::with_capacity(batch * STATE_DIM);
        let mut s2 = Vec::with_capacity(batch * STATE_DIM);
        let mut a = Vec::with_capacity(batch);
        let mut r = Vec::with_capacity(batch);
        let mut d = Vec::with_capacity(batch);
        for t in &items {
            s.extend_from_slice(&t.state);
            s2.extend_from_slice(&t.next_state);
            a.push(t.action as i32);
            r.push(t.reward);
            d.push(if t.done { 1.0f32 } else { 0.0 });
        }

        // Assemble the 33 inputs in manifest order. Param/optimizer state
        // comes from the literal caches; only the batch is marshalled.
        let scalars = [
            lit_f32_scalar(self.adam_step)?,
            lit_f32(&s, &[batch, STATE_DIM])?,
            lit_i32(&a, &[batch])?,
            lit_f32(&r, &[batch])?,
            lit_f32(&s2, &[batch, STATE_DIM])?,
            lit_f32(&d, &[batch])?,
            lit_f32(&weights, &[batch])?,
            lit_f32_scalar(self.cfg.lr)?,
            lit_f32_scalar(self.cfg.gamma)?,
        ];
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(33);
        args.extend(self.params_lits.iter());
        args.extend(self.target_lits.iter());
        args.extend(self.m_lits.iter());
        args.extend(self.v_lits.iter());
        args.extend(scalars.iter());

        let mut outs = self.rt.exec("dqn_train_step", &args)?;
        // 6 params, 6 m, 6 v, step, td_abs, loss
        self.adam_step = scalar_f32(&outs[18])?;
        let td_abs: Vec<f32> = outs[19].to_vec()?;
        let loss = scalar_f32(&outs[20])?;
        // New state: keep the output Literals directly as the caches.
        let mut it = outs.drain(0..18);
        for i in 0..6 {
            self.params_lits[i] = it.next().unwrap();
            self.params.tensors[i] = HostTensor::from_literal(&self.params_lits[i])?;
        }
        for i in 0..6 {
            self.m_lits[i] = it.next().unwrap();
        }
        for i in 0..6 {
            self.v_lits[i] = it.next().unwrap();
        }
        drop(it);

        if let Replay::Prioritized(b) = &mut self.replay {
            b.update_priorities(&idx, &td_abs);
        }

        self.learner_steps_done += 1;
        if self.learner_steps_done % self.cfg.target_sync == 0 {
            self.target_lits = self.params.to_literals()?;
        }
        Ok(loss)
    }

    /// Full training loop: `iters` iterations over random problems from
    /// `problems`, scored by `backend`, rewards normalized by `peak`.
    pub fn train(
        &mut self,
        backend: SharedBackend,
        problems: &[Problem],
        peak: f64,
        iters: usize,
        mut on_iter: impl FnMut(&IterStats),
    ) -> Result<TrainLog> {
        assert!(!problems.is_empty());
        let algo = if self.cfg.prioritized { "apex_dqn" } else { "dqn" };
        let mut log = TrainLog { algo: algo.into(), iters: Vec::new() };
        let mut env = Env::new(problems[0], backend, peak);
        env.mask = self.cfg.feature_mask;
        let t0 = Instant::now();
        let mut env_steps = 0u64;

        for iter in 0..iters {
            let mut rewards = Vec::new();
            for ep in 0..self.cfg.episodes_per_iter {
                let actor = ep % self.cfg.n_actors;
                let eps = self.epsilon(iter, actor);
                let p = *self.rng.choose(problems);
                env.reset(p);
                rewards.push(self.run_episode(&mut env, eps)? as f64);
                env_steps += self.cfg.episode_len as u64;
            }
            let mut loss_sum = 0.0;
            let mut loss_n = 0;
            if self.replay_len() >= self.cfg.learn_start {
                for _ in 0..self.cfg.learner_steps {
                    loss_sum += self.learn()? as f64;
                    loss_n += 1;
                }
            }
            let stats = IterStats {
                iter,
                episode_reward_mean: crate::util::stats::mean(&rewards),
                loss: if loss_n > 0 { loss_sum / loss_n as f64 } else { 0.0 },
                exploration: self.epsilon(iter, 0),
                env_steps,
                wall_secs: t0.elapsed().as_secs_f64(),
            };
            on_iter(&stats);
            log.iters.push(stats);
        }
        Ok(log)
    }
}

/// Q-values through the batch-1 compiled forward for an arbitrary ParamSet
/// (used by [`super::tune`] at inference time).
pub fn q_values_with(rt: &Runtime, params: &ParamSet, state: &[f32]) -> Result<Vec<f32>> {
    let mut args = params.to_literals()?;
    args.push(lit_f32(state, &[1, STATE_DIM])?);
    let outs = rt.exec("q_forward_b1", &args)?;
    Ok(outs[0].to_vec()?)
}
