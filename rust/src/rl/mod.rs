//! Reinforcement-learning trainers (the RLlib analogue, paper §III-D).
//!
//! Five algorithms, as in the paper's Fig. 7 comparison: DQN, APEX_DQN
//! (multi-actor + prioritized replay), PPO, A2C (the synchronous A3C
//! used here — gradient math identical, actor parallelism is logical on
//! this 1-core testbed), and IMPALA (A2C step + V-trace off-policy
//! correction computed by the coordinator).
//!
//! The neural networks and their optimizer updates are **not** implemented
//! in Rust: they were AOT-lowered from JAX/Pallas by `make artifacts` and
//! execute through [`crate::runtime::Runtime`]. Rust owns the MDP loop,
//! replay, exploration, V-trace/GAE, and all orchestration.

pub mod a2c;
pub mod dqn;
pub mod params;
pub mod ppo;
pub mod replay;
pub mod tune;

use crate::runtime::literal::HostTensor;

pub use params::ParamSet;
pub use tune::{tune, tune_masked, TuneOutcome};

/// Per-training-iteration statistics (Fig. 7 plots `episode_reward_mean`).
#[derive(Clone, Debug)]
pub struct IterStats {
    pub iter: usize,
    /// Mean sum-of-rewards per episode in this iteration (normalized GFLOPS
    /// gain, the paper's `episode_reward_mean`).
    pub episode_reward_mean: f64,
    pub loss: f64,
    /// Exploration epsilon (DQN family) or policy entropy (PG family).
    pub exploration: f64,
    pub env_steps: u64,
    pub wall_secs: f64,
}

/// Full training history.
#[derive(Clone, Debug, Default)]
pub struct TrainLog {
    pub algo: String,
    pub iters: Vec<IterStats>,
}

impl TrainLog {
    pub fn to_csv(&self) -> String {
        let mut s =
            String::from("iter,episode_reward_mean,loss,exploration,env_steps,wall_secs\n");
        for it in &self.iters {
            s.push_str(&format!(
                "{},{:.6},{:.6},{:.4},{},{:.3}\n",
                it.iter,
                it.episode_reward_mean,
                it.loss,
                it.exploration,
                it.env_steps,
                it.wall_secs
            ));
        }
        s
    }

    /// Mean episode reward over the last `n` iterations.
    pub fn recent_reward(&self, n: usize) -> f64 {
        let tail: Vec<f64> = self
            .iters
            .iter()
            .rev()
            .take(n)
            .map(|i| i.episode_reward_mean)
            .collect();
        crate::util::stats::mean(&tail)
    }
}

/// Pure-Rust reference of the compiled 3-layer Q-network (ReLU MLP).
/// Used by integration tests to validate the AOT path and by unit tests
/// that need a network without artifacts.
pub fn mlp3_forward(params: &[HostTensor], x: &[f32]) -> Vec<f32> {
    assert_eq!(params.len(), 6);
    let h1 = dense(&params[0], &params[1], x, true);
    let h2 = dense(&params[2], &params[3], &h1, true);
    dense(&params[4], &params[5], &h2, false)
}

/// Pure-Rust reference of the compiled policy/value network.
/// Returns (logits, value).
pub fn pv_forward(params: &[HostTensor], x: &[f32]) -> (Vec<f32>, f32) {
    assert_eq!(params.len(), 8);
    let h1 = dense(&params[0], &params[1], x, true);
    let h2 = dense(&params[2], &params[3], &h1, true);
    let logits = dense(&params[4], &params[5], &h2, false);
    let value = dense(&params[6], &params[7], &h2, false)[0];
    (logits, value)
}

fn dense(w: &HostTensor, b: &HostTensor, x: &[f32], relu: bool) -> Vec<f32> {
    let (k, n) = (w.shape[0], w.shape[1]);
    assert_eq!(x.len(), k, "dense input dim");
    let mut y = b.data.clone();
    for (i, &xv) in x.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        let row = &w.data[i * n..(i + 1) * n];
        for (j, &wv) in row.iter().enumerate() {
            y[j] += xv * wv;
        }
    }
    if relu {
        for v in &mut y {
            *v = v.max(0.0);
        }
    }
    y
}

/// Softmax sampling helpers for the policy-gradient agents.
pub fn log_softmax(logits: &[f32]) -> Vec<f32> {
    let mx = logits.iter().cloned().fold(f32::MIN, f32::max);
    let lse = logits.iter().map(|&l| (l - mx).exp()).sum::<f32>().ln() + mx;
    logits.iter().map(|&l| l - lse).collect()
}

pub fn sample_categorical(logits: &[f32], rng: &mut crate::util::rng::Pcg32) -> usize {
    let lp = log_softmax(logits);
    let r = rng.next_f64();
    let mut acc = 0.0f64;
    for (i, &l) in lp.iter().enumerate() {
        acc += (l as f64).exp();
        if r < acc {
            return i;
        }
    }
    lp.len() - 1
}

pub fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn tiny_params() -> Vec<HostTensor> {
        // 2 -> 3 -> 3 -> 2 MLP with hand-set weights.
        vec![
            HostTensor::new(vec![2, 3], vec![1.0, 0.0, -1.0, 0.0, 1.0, 1.0]),
            HostTensor::new(vec![3], vec![0.0, 0.5, 0.0]),
            HostTensor::new(
                vec![3, 3],
                vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 1.0],
            ),
            HostTensor::new(vec![3], vec![0.0, 0.0, 0.0]),
            HostTensor::new(vec![3, 2], vec![1.0, -1.0, 1.0, 1.0, 0.0, 2.0]),
            HostTensor::new(vec![2], vec![0.1, -0.1]),
        ]
    }

    #[test]
    fn mlp3_forward_hand_computed() {
        let p = tiny_params();
        // x = [1, 2]: h1 = relu([1*1+0, 0+2+0.5, -1+2]) = [1, 2.5, 1]
        // h2 = relu(h1 @ I) = [1, 2.5, 1]
        // out = [1*1 + 2.5*1 + 0 + 0.1, -1 + 2.5 + 2 - 0.1] = [3.6, 3.4]
        let y = mlp3_forward(&p, &[1.0, 2.0]);
        assert!((y[0] - 3.6).abs() < 1e-6, "{y:?}");
        assert!((y[1] - 3.4).abs() < 1e-6, "{y:?}");
    }

    #[test]
    fn log_softmax_normalizes() {
        let lp = log_softmax(&[1.0, 2.0, 3.0]);
        let total: f32 = lp.iter().map(|&l| l.exp()).sum();
        assert!((total - 1.0).abs() < 1e-5);
        assert!(lp[2] > lp[1] && lp[1] > lp[0]);
    }

    #[test]
    fn categorical_sampling_follows_distribution() {
        let mut rng = Pcg32::new(9);
        let logits = [0.0f32, 3.0, 0.0];
        let hits = (0..1000)
            .filter(|_| sample_categorical(&logits, &mut rng) == 1)
            .count();
        assert!(hits > 800, "hits {hits}");
    }

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[2.0]), 0);
    }

    #[test]
    fn train_log_csv() {
        let mut log = TrainLog { algo: "dqn".into(), iters: vec![] };
        log.iters.push(IterStats {
            iter: 0,
            episode_reward_mean: 0.25,
            loss: 1.5,
            exploration: 0.9,
            env_steps: 10,
            wall_secs: 0.1,
        });
        let csv = log.to_csv();
        assert!(csv.contains("iter,episode_reward_mean"));
        assert!(csv.contains("0,0.250000,1.500000,0.9000,10,0.100"));
        assert_eq!(log.recent_reward(5), 0.25);
    }
}
