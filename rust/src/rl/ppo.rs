//! PPO trainer (clipped surrogate, GAE-λ), driving the AOT-compiled
//! `ppo_train_step`. Rollouts are collected on-policy through the
//! batch-1 `pv_forward_b1` artifact; GAE and minibatching happen in Rust.

use super::params::ParamSet;
use super::{IterStats, TrainLog};
use crate::backend::SharedBackend;
use crate::env::actions::Action;
use crate::env::Env;
use crate::ir::Problem;
use crate::runtime::literal::{lit_f32, lit_f32_scalar, lit_i32, scalar_f32, HostTensor};
use crate::runtime::{xla, Runtime};
use crate::util::rng::Pcg32;
use crate::STATE_DIM;
use anyhow::Result;
use std::sync::Arc;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct PpoConfig {
    pub gamma: f32,
    pub lam: f32,
    pub lr: f32,
    pub clip_eps: f32,
    pub ent_coef: f32,
    pub episode_len: usize,
    /// Episodes per rollout (one iteration trains on one rollout).
    pub episodes_per_iter: usize,
    /// SGD epochs over the rollout per iteration.
    pub epochs: usize,
    pub seed: u64,
}

impl Default for PpoConfig {
    fn default() -> Self {
        PpoConfig {
            gamma: 0.9,
            lam: 0.95,
            lr: 3e-4,
            clip_eps: 0.2,
            ent_coef: 0.01,
            episode_len: 10,
            episodes_per_iter: 6,
            epochs: 3,
            seed: 1,
        }
    }
}

/// One rollout step.
#[derive(Clone, Debug)]
pub struct RolloutStep {
    pub state: Vec<f32>,
    pub action: usize,
    pub reward: f32,
    pub logp: f32,
    pub value: f32,
}

/// Compute GAE advantages + returns for one episode (terminal bootstrap 0).
pub fn gae(steps: &[RolloutStep], gamma: f32, lam: f32) -> (Vec<f32>, Vec<f32>) {
    let n = steps.len();
    let mut adv = vec![0.0f32; n];
    let mut next_adv = 0.0f32;
    let mut next_value = 0.0f32;
    for t in (0..n).rev() {
        let delta = steps[t].reward + gamma * next_value - steps[t].value;
        next_adv = delta + gamma * lam * next_adv;
        adv[t] = next_adv;
        next_value = steps[t].value;
    }
    let ret: Vec<f32> = adv.iter().zip(steps).map(|(a, s)| a + s.value).collect();
    (adv, ret)
}

/// Normalize advantages to zero mean / unit std (standard PPO practice).
pub fn normalize(adv: &mut [f32]) {
    let n = adv.len() as f32;
    if n < 2.0 {
        return;
    }
    let mean: f32 = adv.iter().sum::<f32>() / n;
    let var: f32 = adv.iter().map(|a| (a - mean) * (a - mean)).sum::<f32>() / n;
    let std = var.sqrt().max(1e-6);
    for a in adv {
        *a = (*a - mean) / std;
    }
}

/// Policy/value forward through the batch-1 artifact.
pub fn pv_with(rt: &Runtime, params: &ParamSet, state: &[f32]) -> Result<(Vec<f32>, f32)> {
    pv_with_lits(rt, &params.to_literals()?, state)
}

/// Same, over pre-marshalled param Literals (hot-path variant).
pub fn pv_with_lits(
    rt: &Runtime,
    params: &[xla::Literal],
    state: &[f32],
) -> Result<(Vec<f32>, f32)> {
    let state_lit = lit_f32(state, &[1, STATE_DIM])?;
    let mut args: Vec<&xla::Literal> = params.iter().collect();
    args.push(&state_lit);
    let outs = rt.exec("pv_forward_b1", &args)?;
    let logits: Vec<f32> = outs[0].to_vec()?;
    let value: Vec<f32> = outs[1].to_vec()?;
    Ok((logits, value[0]))
}

pub struct PpoTrainer {
    rt: Arc<Runtime>,
    pub cfg: PpoConfig,
    pub params: ParamSet,
    adam_step: f32,
    rng: Pcg32,
    // SPerf: params/optimizer state cached as Literals between PJRT calls.
    params_lits: Vec<xla::Literal>,
    m_lits: Vec<xla::Literal>,
    v_lits: Vec<xla::Literal>,
}

impl PpoTrainer {
    pub fn new(rt: Arc<Runtime>, cfg: PpoConfig) -> Result<Self> {
        let params = ParamSet::init(&rt, "pv_init", cfg.seed as i32)?;
        let params_lits = params.to_literals()?;
        let m_lits = params.zeros_like().to_literals()?;
        let v_lits = params.zeros_like().to_literals()?;
        let rng = Pcg32::new(cfg.seed ^ 0x99_0000);
        Ok(PpoTrainer { rt, cfg, params, adam_step: 0.0, rng, params_lits, m_lits, v_lits })
    }

    /// Forward through the cached param Literals (no per-step marshal).
    fn pv_cached(&self, state: &[f32]) -> Result<(Vec<f32>, f32)> {
        pv_with_lits(&self.rt, &self.params_lits, state)
    }

    fn collect_episode(&mut self, env: &mut Env) -> Result<(Vec<RolloutStep>, f32)> {
        let mut steps = Vec::with_capacity(self.cfg.episode_len);
        let mut state = env.state();
        let mut total = 0.0f32;
        for _ in 0..self.cfg.episode_len {
            let (logits, value) = self.pv_cached(&state)?;
            let a = super::sample_categorical(&logits, &mut self.rng);
            let logp = super::log_softmax(&logits)[a];
            let action = Action::from_index(a)
                .ok_or_else(|| anyhow::anyhow!("action index {a} out of range"))?;
            let st = env.step(action);
            total += st.reward;
            steps.push(RolloutStep {
                state: std::mem::take(&mut state),
                action: a,
                reward: st.reward,
                logp,
                value,
            });
            state = st.state;
        }
        Ok((steps, total))
    }

    /// One minibatch through the compiled `ppo_train_step`.
    /// `batch` entries index into the flattened rollout arrays.
    fn update_minibatch(
        &mut self,
        steps: &[RolloutStep],
        adv: &[f32],
        ret: &[f32],
        batch_idx: &[usize],
    ) -> Result<(f32, f32, f32)> {
        let b = self.rt.constants.batch;
        assert_eq!(batch_idx.len(), b);
        let mut s = Vec::with_capacity(b * STATE_DIM);
        let mut a = Vec::with_capacity(b);
        let mut ad = Vec::with_capacity(b);
        let mut rt_ = Vec::with_capacity(b);
        let mut lp = Vec::with_capacity(b);
        for &i in batch_idx {
            s.extend_from_slice(&steps[i].state);
            a.push(steps[i].action as i32);
            ad.push(adv[i]);
            rt_.push(ret[i]);
            lp.push(steps[i].logp);
        }
        let tail = [
            lit_f32_scalar(self.adam_step)?,
            lit_f32(&s, &[b, STATE_DIM])?,
            lit_i32(&a, &[b])?,
            lit_f32(&ad, &[b])?,
            lit_f32(&rt_, &[b])?,
            lit_f32(&lp, &[b])?,
            lit_f32_scalar(self.cfg.lr)?,
            lit_f32_scalar(self.cfg.clip_eps)?,
            lit_f32_scalar(self.cfg.ent_coef)?,
        ];
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(33);
        args.extend(self.params_lits.iter());
        args.extend(self.m_lits.iter());
        args.extend(self.v_lits.iter());
        args.extend(tail.iter());

        let mut outs = self.rt.exec("ppo_train_step", &args)?;
        self.adam_step = scalar_f32(&outs[24])?;
        let loss = scalar_f32(&outs[25])?;
        let kl = scalar_f32(&outs[26])?;
        let ent = scalar_f32(&outs[27])?;
        let mut it = outs.drain(0..24);
        for i in 0..8 {
            self.params_lits[i] = it.next().unwrap();
            self.params.tensors[i] = HostTensor::from_literal(&self.params_lits[i])?;
        }
        for i in 0..8 {
            self.m_lits[i] = it.next().unwrap();
        }
        for i in 0..8 {
            self.v_lits[i] = it.next().unwrap();
        }
        drop(it);
        Ok((loss, kl, ent))
    }

    pub fn train(
        &mut self,
        backend: SharedBackend,
        problems: &[Problem],
        peak: f64,
        iters: usize,
        mut on_iter: impl FnMut(&IterStats),
    ) -> Result<TrainLog> {
        let mut log = TrainLog { algo: "ppo".into(), iters: Vec::new() };
        let mut env = Env::new(problems[0], backend, peak);
        let t0 = Instant::now();
        let mut env_steps = 0u64;
        let b = self.rt.constants.batch;

        for iter in 0..iters {
            // ---- collect rollout ----
            let mut steps: Vec<RolloutStep> = Vec::new();
            let mut adv: Vec<f32> = Vec::new();
            let mut ret: Vec<f32> = Vec::new();
            let mut rewards = Vec::new();
            for _ in 0..self.cfg.episodes_per_iter {
                let p = *self.rng.choose(problems);
                env.reset(p);
                let (ep, total) = self.collect_episode(&mut env)?;
                env_steps += ep.len() as u64;
                let (mut ea, er) = gae(&ep, self.cfg.gamma, self.cfg.lam);
                adv.append(&mut ea);
                ret.extend(er);
                steps.extend(ep);
                rewards.push(total as f64);
            }
            normalize(&mut adv);

            // ---- minibatch SGD epochs ----
            let mut idx: Vec<usize> = (0..steps.len()).collect();
            let (mut loss_s, mut ent_s, mut nb) = (0.0f64, 0.0f64, 0usize);
            for _ in 0..self.cfg.epochs {
                self.rng.shuffle(&mut idx);
                for chunk in idx.chunks(b) {
                    // Shape-specialized artifact: pad short chunks by
                    // resampling from the rollout.
                    let mut batch: Vec<usize> = chunk.to_vec();
                    while batch.len() < b {
                        batch.push(idx[self.rng.below(idx.len())]);
                    }
                    let (l, _kl, e) =
                        self.update_minibatch(&steps, &adv, &ret, &batch)?;
                    loss_s += l as f64;
                    ent_s += e as f64;
                    nb += 1;
                }
            }
            let stats = IterStats {
                iter,
                episode_reward_mean: crate::util::stats::mean(&rewards),
                loss: loss_s / nb.max(1) as f64,
                exploration: ent_s / nb.max(1) as f64,
                env_steps,
                wall_secs: t0.elapsed().as_secs_f64(),
            };
            on_iter(&stats);
            log.iters.push(stats);
        }
        Ok(log)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step(r: f32, v: f32) -> RolloutStep {
        RolloutStep { state: vec![], action: 0, reward: r, logp: -1.0, value: v }
    }

    #[test]
    fn gae_matches_hand_computation() {
        // Two steps, gamma=1, lam=1: pure Monte-Carlo advantage.
        let eps = [step(1.0, 0.5), step(2.0, 0.25)];
        let (adv, ret) = gae(&eps, 1.0, 1.0);
        // ret_t = sum of future rewards; adv = ret - value.
        assert!((ret[0] - 3.0).abs() < 1e-6, "{ret:?}");
        assert!((ret[1] - 2.0).abs() < 1e-6);
        assert!((adv[0] - 2.5).abs() < 1e-6, "{adv:?}");
        assert!((adv[1] - 1.75).abs() < 1e-6);
    }

    #[test]
    fn gae_lambda_zero_is_td() {
        let eps = [step(1.0, 0.5), step(2.0, 0.25)];
        let (adv, _) = gae(&eps, 0.9, 0.0);
        // lam=0: adv_t = r_t + gamma*V_{t+1} - V_t
        assert!((adv[0] - (1.0 + 0.9 * 0.25 - 0.5)).abs() < 1e-6);
        assert!((adv[1] - (2.0 - 0.25)).abs() < 1e-6);
    }

    #[test]
    fn normalize_zero_mean_unit_std() {
        let mut a = vec![1.0, 2.0, 3.0, 4.0];
        normalize(&mut a);
        let mean: f32 = a.iter().sum::<f32>() / 4.0;
        let var: f32 = a.iter().map(|x| x * x).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-4);
    }
}
