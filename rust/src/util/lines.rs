//! Bounded line reading for the serving front door (DESIGN.md §13).
//!
//! `BufRead::lines` buffers a whole line before handing it over, so one
//! caller writing an endless byte stream with no `\n` grows the server's
//! memory without bound. [`BoundedLines`] reads at most `max_bytes` of a
//! line into memory: a longer line is *drained* (consumed from the
//! reader's own buffer up to the next terminator, never materialized) and
//! reported as [`Line::Oversized`] so the caller can emit a structured
//! rejection and keep serving the stream. A final line without a trailing
//! newline is still yielded — a truncated request file serves its last
//! request instead of silently dropping it.

use std::io::BufRead;

/// One item from a bounded line stream.
#[derive(Debug, PartialEq, Eq)]
pub enum Line {
    /// A complete line within the byte bound (terminator stripped, CRLF
    /// tolerated, invalid UTF-8 replaced).
    Text(String),
    /// A line longer than the bound: drained from the stream and
    /// discarded; `bytes` is the total length seen (excluding the
    /// terminator).
    Oversized {
        /// Total bytes the line carried before its terminator.
        bytes: usize,
    },
}

/// Iterator over `\n`-separated lines of `r`, holding at most
/// `max_bytes` of any one line in memory. I/O errors end the stream
/// (reported once via [`BoundedLines::take_error`]).
pub struct BoundedLines<R: BufRead> {
    r: R,
    max_bytes: usize,
    err: Option<std::io::Error>,
    done: bool,
}

impl<R: BufRead> BoundedLines<R> {
    /// Bounded line iterator; `max_bytes` is clamped to at least 1.
    pub fn new(r: R, max_bytes: usize) -> Self {
        BoundedLines { r, max_bytes: max_bytes.max(1), err: None, done: false }
    }

    /// The I/O error that terminated the stream, if any.
    pub fn take_error(&mut self) -> Option<std::io::Error> {
        self.err.take()
    }

    /// Consume the rest of the current (oversized) line straight out of
    /// the reader's internal buffer — exact to the byte, so the next line
    /// starts immediately after the terminator. Returns bytes discarded.
    fn drain_to_newline(&mut self) -> usize {
        let mut discarded = 0usize;
        loop {
            let available = match self.r.fill_buf() {
                Ok(b) => b,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    self.err = Some(e);
                    self.done = true;
                    return discarded;
                }
            };
            if available.is_empty() {
                return discarded; // EOF mid-line
            }
            match available.iter().position(|&b| b == b'\n') {
                Some(pos) => {
                    self.r.consume(pos + 1);
                    return discarded + pos;
                }
                None => {
                    let n = available.len();
                    self.r.consume(n);
                    discarded += n;
                }
            }
        }
    }
}

impl<R: BufRead> Iterator for BoundedLines<R> {
    type Item = Line;

    fn next(&mut self) -> Option<Line> {
        if self.done {
            return None;
        }
        // Read up to max_bytes + 1 raw bytes so "exactly at the bound"
        // (terminator included in the +1) and "over the bound" stay
        // distinguishable.
        let mut buf: Vec<u8> = Vec::new();
        let limit = self.max_bytes as u64 + 1;
        use std::io::Read;
        match (&mut self.r).take(limit).read_until(b'\n', &mut buf) {
            Ok(0) => {
                self.done = true;
                return None;
            }
            Ok(_) => {}
            Err(e) => {
                self.err = Some(e);
                self.done = true;
                return None;
            }
        }
        if buf.last() == Some(&b'\n') {
            buf.pop();
            if buf.last() == Some(&b'\r') {
                buf.pop();
            }
        }
        if buf.len() > self.max_bytes {
            let total = buf.len() + self.drain_to_newline();
            return Some(Line::Oversized { bytes: total });
        }
        Some(Line::Text(String::from_utf8_lossy(&buf).into_owned()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn collect(input: &str, max: usize) -> Vec<Line> {
        BoundedLines::new(Cursor::new(input.as_bytes().to_vec()), max).collect()
    }

    #[test]
    fn yields_lines_and_strips_terminators() {
        let lines = collect("a\nbb\r\nccc\n", 16);
        assert_eq!(
            lines,
            vec![
                Line::Text("a".into()),
                Line::Text("bb".into()),
                Line::Text("ccc".into())
            ]
        );
    }

    #[test]
    fn final_line_without_newline_is_served() {
        let lines = collect("first\nlast-no-newline", 64);
        assert_eq!(
            lines,
            vec![Line::Text("first".into()), Line::Text("last-no-newline".into())]
        );
    }

    #[test]
    fn oversized_line_is_rejected_and_stream_recovers() {
        let big = "x".repeat(100);
        let input = format!("ok1\n{big}\nok2\n");
        let lines = collect(&input, 10);
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[0], Line::Text("ok1".into()));
        assert!(matches!(lines[1], Line::Oversized { bytes } if bytes == 100));
        assert_eq!(lines[2], Line::Text("ok2".into()));
    }

    #[test]
    fn line_exactly_at_the_bound_is_accepted() {
        let exact = "y".repeat(10);
        let lines = collect(&format!("{exact}\nz\n"), 10);
        assert_eq!(lines, vec![Line::Text(exact), Line::Text("z".into())]);
    }

    #[test]
    fn oversized_final_line_without_newline_is_rejected() {
        let big = "x".repeat(50);
        let lines = collect(&big, 10);
        assert_eq!(lines.len(), 1);
        assert!(matches!(lines[0], Line::Oversized { bytes } if bytes == 50));
    }

    #[test]
    fn oversized_line_streams_without_materializing() {
        // A 4 MiB line against a 1 KiB bound flows through the reader's
        // own buffer: Oversized carries a byte count, never the bytes.
        let big = vec![b'q'; 4 << 20];
        let mut input = big;
        input.push(b'\n');
        input.extend_from_slice(b"after\n");
        let lines: Vec<Line> = BoundedLines::new(Cursor::new(input), 1024).collect();
        assert!(matches!(lines[0], Line::Oversized { bytes } if bytes == (4 << 20)));
        assert_eq!(lines[1], Line::Text("after".into()));
    }
}
