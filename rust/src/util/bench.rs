//! Mini benchmark harness (criterion is not in the offline crate cache).
//!
//! Provides warmup + timed repeats with min/mean/p50 reporting, matching
//! how the paper's LoopNest measures kernels ("excludes the first
//! iterations as a warm-up and times multiple executions, taking the
//! fastest measurement"). Used both by `rust/benches/*` (with
//! `harness = false`) and by the backend executor's GFLOPS measurement.

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub min: Duration,
    pub mean: Duration,
    pub median: Duration,
}

impl BenchResult {
    pub fn min_secs(&self) -> f64 {
        self.min.as_secs_f64()
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} iters={:<5} min={:>12?} mean={:>12?} p50={:>12?}",
            self.name, self.iters, self.min, self.mean, self.median
        )
    }
}

/// Run `f` with warmup, then time repeats until `budget` elapses (at least
/// `min_iters`). Returns min/mean/median of per-iteration wall time.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, min_iters: usize, mut f: F) -> BenchResult {
    // Warmup: run until ~20% of budget or 3 iterations, whichever first.
    let warm_deadline = Instant::now() + budget.mul_f64(0.2);
    let mut warm = 0;
    while warm < 3 || (Instant::now() < warm_deadline && warm < 20) {
        f();
        warm += 1;
        if Instant::now() >= warm_deadline && warm >= 3 {
            break;
        }
    }

    let mut times = Vec::new();
    let deadline = Instant::now() + budget;
    while times.len() < min_iters || (Instant::now() < deadline && times.len() < 10_000) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
        if Instant::now() >= deadline && times.len() >= min_iters {
            break;
        }
    }

    let mut sorted = times.clone();
    sorted.sort();
    let total: Duration = times.iter().sum();
    BenchResult {
        name: name.to_string(),
        iters: times.len(),
        min: sorted[0],
        mean: total / times.len() as u32,
        median: sorted[sorted.len() / 2],
    }
}

/// Convenience: bench and print one line.
pub fn run<F: FnMut()>(name: &str, budget: Duration, min_iters: usize, f: F) -> BenchResult {
    let r = bench(name, budget, min_iters, f);
    println!("{r}");
    r
}

/// Time one invocation of `f`, returning its result and the wall seconds.
/// Used by the scaling benches, where one batch run IS the measurement
/// (warmup + repeats would multiply an already-long workload).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Parallel speedup of `parallel_secs` relative to `serial_secs`.
pub fn speedup(serial_secs: f64, parallel_secs: f64) -> f64 {
    serial_secs / parallel_secs.max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_once_and_speedup() {
        let ((), secs) = time_once(|| std::thread::sleep(Duration::from_millis(5)));
        assert!(secs >= 0.004, "{secs}");
        assert!((speedup(4.0, 1.0) - 4.0).abs() < 1e-12);
        assert!(speedup(1.0, 0.0).is_finite());
    }

    #[test]
    fn bench_reports_sane_times() {
        let mut x = 0u64;
        let r = bench("spin", Duration::from_millis(20), 5, || {
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(r.iters >= 5);
        assert!(r.min <= r.median && r.median <= r.mean * 3);
    }
}
