//! Small self-contained infrastructure (offline build: no external crates
//! beyond `xla`/`anyhow`, so RNG / JSON / benching are hand-rolled here).

pub mod bench;
pub mod json;
pub mod rng;
pub mod stats;

/// Integer ceil-division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// floor(log2(x)) for x >= 1.
#[inline]
pub fn ilog2(x: usize) -> u32 {
    debug_assert!(x >= 1);
    usize::BITS - 1 - x.leading_zeros()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(1, 64), 1);
        assert_eq!(ceil_div(0, 5), 0);
    }

    #[test]
    fn ilog2_basics() {
        assert_eq!(ilog2(1), 0);
        assert_eq!(ilog2(2), 1);
        assert_eq!(ilog2(3), 1);
        assert_eq!(ilog2(1024), 10);
        assert_eq!(ilog2(usize::MAX), usize::BITS - 1);
    }
}
