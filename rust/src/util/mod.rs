//! Small self-contained infrastructure (offline build: no external crates
//! beyond `xla`/`anyhow`, so RNG / JSON / benching are hand-rolled here).

pub mod bench;
pub mod json;
pub mod lines;
pub mod rng;
pub mod stats;

/// Integer ceil-division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// Default worker-thread count: every available core (1 if unknown).
/// The single source of truth for every fan-out default in the crate.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Compute `f(0..n)` on up to `threads` scoped worker threads pulling
/// indices from a shared atomic counter; results come back in index order.
/// `threads <= 1` (or `n <= 1`) runs inline with no thread overhead.
/// The shared work-distribution loop behind `SearchCtx::expand` and the
/// `tune-many` batch driver.
pub fn parallel_indexed_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = threads.min(n);
    if workers <= 1 {
        return (0..n).map(f).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let f = &f;
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                let next = &next;
                s.spawn(move || {
                    let mut local = Vec::new();
                    loop {
                        let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, f(i)));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            for (i, v) in h.join().expect("parallel worker panicked") {
                slots[i] = Some(v);
            }
        }
    });
    slots
        .into_iter()
        .map(|v| v.expect("every index computed exactly once"))
        .collect()
}

/// floor(log2(x)) for x >= 1.
#[inline]
pub fn ilog2(x: usize) -> u32 {
    debug_assert!(x >= 1);
    usize::BITS - 1 - x.leading_zeros()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(1, 64), 1);
        assert_eq!(ceil_div(0, 5), 0);
    }

    #[test]
    fn parallel_indexed_map_orders_results() {
        for threads in [1usize, 3, 8] {
            let out = parallel_indexed_map(17, threads, |i| i * i);
            assert_eq!(out, (0..17).map(|i| i * i).collect::<Vec<_>>(), "{threads}");
        }
        assert!(parallel_indexed_map(0, 4, |i| i).is_empty());
    }

    #[test]
    fn ilog2_basics() {
        assert_eq!(ilog2(1), 0);
        assert_eq!(ilog2(2), 1);
        assert_eq!(ilog2(3), 1);
        assert_eq!(ilog2(1024), 10);
        assert_eq!(ilog2(usize::MAX), usize::BITS - 1);
    }
}
