//! Minimal JSON parser — just enough for `artifacts/manifest.json`
//! (objects, arrays, strings, numbers, booleans, null). Hand-rolled because
//! serde_json is not in the offline crate cache.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Multi-byte UTF-8: copy raw bytes of the sequence.
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    let end = (start + len).min(self.bytes.len());
                    self.pos = end;
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
                None => return Err(self.err("eof in string")),
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Minimal JSON writer (for results files).
pub fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if !n.is_finite() {
                // JSON has no NaN/Infinity literal; `n.to_string()` would
                // emit invalid JSON. Null is the portable encoding of "no
                // measurable value" (e.g. GFLOPS of a failed eval).
                out.push_str("null");
            } else if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&(*n as i64).to_string());
            } else {
                out.push_str(&n.to_string());
            }
        }
        Json::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '\r' => out.push_str("\\r"),
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(&Json::Str(k.clone()), out);
                out.push(':');
                write_json(x, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_doc() {
        let doc = r#"{
            "constants": {"state_dim": 200, "batch": 64},
            "entries": {
                "q_init": {"file": "q_init.hlo.txt",
                           "inputs": [{"shape": [], "dtype": "int32"}],
                           "num_outputs": 6}
            }
        }"#;
        let v = parse(doc).unwrap();
        assert_eq!(
            v.get("constants").unwrap().get("state_dim").unwrap().as_usize(),
            Some(200)
        );
        let entry = v.get("entries").unwrap().get("q_init").unwrap();
        assert_eq!(entry.get("file").unwrap().as_str(), Some("q_init.hlo.txt"));
        assert_eq!(entry.get("num_outputs").unwrap().as_usize(), Some(6));
        let inputs = entry.get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(inputs[0].get("dtype").unwrap().as_str(), Some("int32"));
        assert_eq!(inputs[0].get("shape").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn parses_scalars_and_arrays() {
        assert_eq!(parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(
            parse("[1, 2, 3]").unwrap(),
            Json::Arr(vec![Json::Num(1.0), Json::Num(2.0), Json::Num(3.0)])
        );
    }

    #[test]
    fn string_escapes() {
        assert_eq!(
            parse(r#""a\nb\t\"c\" A""#).unwrap(),
            Json::Str("a\nb\t\"c\" A".to_string())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("hello").is_err());
        assert!(parse("{\"a\": 1} x").is_err());
    }

    #[test]
    fn non_finite_numbers_emit_null() {
        // `NaN`/`inf` have no JSON literal; emitting them verbatim would
        // produce an unparseable document.
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let mut s = String::new();
            write_json(&Json::Num(bad), &mut s);
            assert_eq!(s, "null");
        }
        let mut s = String::new();
        write_json(
            &Json::Arr(vec![Json::Num(1.5), Json::Num(f64::NAN), Json::Num(2.0)]),
            &mut s,
        );
        assert_eq!(s, "[1.5,null,2]");
        // The emitted document parses back (null, not a bare NaN token).
        assert_eq!(
            parse(&s).unwrap(),
            Json::Arr(vec![Json::Num(1.5), Json::Null, Json::Num(2.0)])
        );
        // Finite extremes still round-trip as numbers.
        let mut s = String::new();
        write_json(&Json::Num(1e300), &mut s);
        assert_eq!(parse(&s).unwrap(), Json::Num(1e300));
    }

    #[test]
    fn roundtrip_write() {
        let doc = r#"{"a": [1, 2.5, "x"], "b": {"c": true}}"#;
        let v = parse(doc).unwrap();
        let mut s = String::new();
        write_json(&v, &mut s);
        assert_eq!(parse(&s).unwrap(), v);
    }
}
