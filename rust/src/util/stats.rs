//! Small statistics helpers used by the evaluation harness and benches.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean; 0.0 for empty input. Requires positive entries.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.max(1e-300).ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100) with linear interpolation.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Median.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(geomean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
    }
}
