//! PCG32 pseudo-random generator (O'Neill 2014) — deterministic, seedable,
//! no external crates. Used by the dataset split, epsilon-greedy
//! exploration, replay sampling, stochastic baselines, and the seeded
//! property tests.

/// PCG-XSH-RR 64/32.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, bound) via Lemire rejection.
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0 && bound <= u32::MAX as usize);
        let bound = bound as u32;
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u32();
            if r >= threshold {
                return (r % bound) as usize;
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Standard normal via Box-Muller.
    pub fn next_normal(&mut self) -> f64 {
        let u1 = (1.0 - self.next_f64()).max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg32::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = rng.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut rng = Pcg32::new(3);
        for _ in 0..1000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::new(11);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::new(5);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
