//! State featurizer (paper §III-C, Figs. 4–5).
//!
//! The graph representation (loops / data / computation nodes; nesting,
//! data-flow, and stride edges) is implicit in the [`Nest`] + tensor access
//! functions; this module aggregates it into the vector representation the
//! networks consume: **20 values per loop**, `MAX_LOOPS` loops, zero-padded:
//!
//! 1. agent-cursor bit
//! 2. loop size (trip count), log2-scaled
//! 3. loop tail, log2-scaled
//! 4. nest-kind feature: write-back loop 0, serial compute loop 1,
//!    parallel-marked compute loop 2 (the `parallelize` mark rides the
//!    existing slot, so `FEATS`/`STATE_DIM` — and with them every AOT
//!    artifact shape — are unchanged by the parallel contract)
//! 5–20. 16-bin histogram of memory-access stride frequencies, bins of
//!    size 2^N, N in 0..=15 (cache-line-scale discretization)
//!
//! The memory stride a loop induces on a tensor = (IR stride of the loop,
//! in elements of its dim) x (the tensor's access-map element stride w.r.t.
//! that dim, see [`crate::ir::Access`]). Loops that do not index a tensor
//! produce no access (stride-0 reuse is not counted — documented deviation;
//! the paper's figure counts strides >= 1). Because the histogram is
//! computed from the problem's access maps, the same code featurizes every
//! workload family (matmul, batched matmul, conv, MLP) with no special
//! cases.
//!
//! Sizes/tails are log2-scaled before entering the network: the paper
//! reports integer features but does not specify input scaling; raw extents
//! up to 256 destabilize an MLP, and log-scaling is monotone, so ordering
//! information is preserved.

use crate::ir::{Kind, Nest};
use crate::{FEATS, STATE_DIM};

pub const HIST_BINS: usize = 16;

/// Feature-group mask for ablation studies (EXPERIMENTS.md §Ablations):
/// disabled groups are zeroed in the state vector, testing the paper's
/// claim that this is "a minimal set of features for the RL algorithm to
/// learn memory access patterns" (§III-C).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FeatureMask {
    pub cursor: bool,
    pub size: bool,
    pub tail: bool,
    pub kind: bool,
    pub hist: bool,
}

impl Default for FeatureMask {
    fn default() -> Self {
        FeatureMask { cursor: true, size: true, tail: true, kind: true, hist: true }
    }
}

impl FeatureMask {
    pub fn apply(&self, v: &mut [f32]) {
        debug_assert_eq!(v.len(), crate::STATE_DIM);
        for chunk in v.chunks_mut(FEATS) {
            if !self.cursor {
                chunk[0] = 0.0;
            }
            if !self.size {
                chunk[1] = 0.0;
            }
            if !self.tail {
                chunk[2] = 0.0;
            }
            if !self.kind {
                chunk[3] = 0.0;
            }
            if !self.hist {
                chunk[4..].fill(0.0);
            }
        }
    }
}

/// Feature vector for one loop.
pub fn loop_features(nest: &Nest, idx: usize, out: &mut [f32]) {
    assert_eq!(out.len(), FEATS);
    let l = nest.loops[idx];
    out.fill(0.0);
    out[0] = if idx == nest.cursor { 1.0 } else { 0.0 };
    out[1] = log2f(nest.trip(idx));
    out[2] = log2f(nest.tail(idx));
    out[3] = match (l.kind, l.parallel) {
        (Kind::WriteBack, _) => 0.0,
        (Kind::Compute, false) => 1.0,
        (Kind::Compute, true) => 2.0,
    };

    let tensors = match l.kind {
        Kind::Compute => nest.problem.compute_tensors(),
        Kind::WriteBack => nest.problem.writeback_tensors(),
    };
    let ir_stride = nest.stride(idx);
    for t in tensors.iter() {
        if let Some(ts) = t.access.stride(l.dim) {
            let mem_stride = ir_stride * ts;
            let bin = (crate::util::ilog2(mem_stride.max(1)) as usize).min(HIST_BINS - 1);
            out[4 + bin] += 1.0;
        }
    }
}

fn log2f(x: usize) -> f32 {
    ((x + 1) as f32).log2()
}

/// Full state vector: `MAX_LOOPS * FEATS` f32, zero-padded past the actual
/// loop count.
pub fn state_vector(nest: &Nest) -> Vec<f32> {
    let mut v = vec![0.0f32; STATE_DIM];
    for i in 0..nest.loops.len().min(crate::ir::MAX_LOOPS) {
        loop_features(nest, i, &mut v[i * FEATS..(i + 1) * FEATS]);
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Nest, Problem};
    use crate::util::rng::Pcg32;

    fn nest() -> Nest {
        Nest::initial(Problem::new(64, 96, 128))
    }

    #[test]
    fn vector_has_fixed_length_and_padding() {
        let v = state_vector(&nest());
        assert_eq!(v.len(), STATE_DIM);
        // 5 loops used; the rest must be zero.
        assert!(v[5 * FEATS..].iter().all(|&x| x == 0.0));
        assert!(v[..5 * FEATS].iter().any(|&x| x != 0.0));
    }

    #[test]
    fn cursor_bit_tracks_cursor() {
        let mut n = nest();
        let v = state_vector(&n);
        assert_eq!(v[0], 1.0);
        assert_eq!(v[FEATS], 0.0);
        n.cursor_down().unwrap();
        let v = state_vector(&n);
        assert_eq!(v[0], 0.0);
        assert_eq!(v[FEATS], 1.0);
    }

    #[test]
    fn nest_kind_bit() {
        let n = nest();
        let v = state_vector(&n);
        assert_eq!(v[3], 1.0); // compute m
        assert_eq!(v[3 * FEATS + 3], 0.0); // write-back m
    }

    #[test]
    fn parallel_mark_is_visible_to_the_network() {
        let mut n = nest();
        n.split(16).unwrap();
        n.parallelize().unwrap();
        let v = state_vector(&n);
        assert_eq!(v[3], 2.0); // parallel compute m root
        assert_eq!(v[FEATS + 3], 1.0); // serial compute m:16 tile
        // The kind mask still zeroes the slot.
        let mut masked = v.clone();
        FeatureMask { kind: false, ..Default::default() }.apply(&mut masked);
        assert!(masked.chunks(FEATS).all(|c| c[3] == 0.0));
    }

    #[test]
    fn stride_histogram_for_initial_matmul() {
        // m loop (stride 1 in dim units): A stride k=128 -> bin 7,
        // T stride n=96 -> bin log2(96)=6. B not indexed by m.
        let n = nest();
        let mut f = [0.0f32; FEATS];
        loop_features(&n, 0, &mut f);
        assert_eq!(f[4 + 7], 1.0, "A access at bin 7: {f:?}");
        assert_eq!(f[4 + 6], 1.0, "T access at bin 6: {f:?}");
        assert_eq!(f[4..].iter().sum::<f32>(), 2.0);

        // k loop: A stride 1 -> bin 0, B stride 96 -> bin 6.
        let mut f = [0.0f32; FEATS];
        loop_features(&n, 2, &mut f);
        assert_eq!(f[4], 1.0);
        assert_eq!(f[4 + 6], 1.0);
    }

    #[test]
    fn tiling_shifts_stride_bins() {
        let mut n = nest();
        // Split m by 16: the m root now advances 16 rows per iteration ->
        // A stride 16*128 = 2048 -> bin 11.
        n.split(16).unwrap();
        let mut f = [0.0f32; FEATS];
        loop_features(&n, 0, &mut f);
        assert_eq!(f[4 + 11], 1.0, "{f:?}");
    }

    #[test]
    fn histogram_clamps_to_last_bin() {
        // Huge strides all land in bin 15.
        let n = Nest::initial(Problem::new(256, 256, 256));
        let mut big = n.clone();
        big.cursor = 0;
        // m stride on A = k = 256 -> bin 8; not clamped. Build an
        // artificially deep tiling to push stride over 2^15.
        for _ in 0..3 {
            big.cursor = 0;
            let _ = big.split(8);
        }
        let mut f = [0.0f32; FEATS];
        loop_features(&big, 0, &mut f);
        let nz: Vec<usize> =
            (0..HIST_BINS).filter(|&b| f[4 + b] > 0.0).collect();
        assert!(!nz.is_empty());
        assert!(nz.iter().all(|&b| b <= 15));
    }

    /// Property: histogram mass equals the number of (tensor, dim) accesses
    /// of the loop's nest kind, for random schedules.
    #[test]
    fn prop_histogram_mass_conserved() {
        for seed in 0..30u64 {
            let mut rng = Pcg32::new(seed ^ 0xfea7);
            let mut n = nest();
            for _ in 0..40 {
                match rng.below(5) {
                    0 => drop(n.cursor_up()),
                    1 => drop(n.cursor_down()),
                    2 => drop(n.swap_up()),
                    3 => drop(n.swap_down()),
                    _ => drop(n.split(*rng.choose(&[2usize, 4, 8, 16]))),
                }
            }
            for (i, l) in n.loops.iter().enumerate() {
                let mut f = [0.0f32; FEATS];
                loop_features(&n, i, &mut f);
                let tensors = match l.kind {
                    Kind::Compute => n.problem.compute_tensors(),
                    Kind::WriteBack => n.problem.writeback_tensors(),
                };
                let expected = tensors
                    .iter()
                    .filter(|t| t.access.indexed(l.dim))
                    .count() as f32;
                let mass: f32 = f[4..].iter().sum();
                assert_eq!(mass, expected, "seed {seed} loop {i}");
            }
        }
    }

    #[test]
    fn histogram_covers_generalized_workloads() {
        // conv2d oh loop: In stride iw=30 (bin log2(30)=4) counted twice
        // (oh and kh share the stride but only oh is this loop's dim ->
        // once), T stride ow=28 -> bin 4. W not indexed by oh.
        let n = Nest::initial(Problem::conv2d(28, 28, 3, 3));
        let mut f = [0.0f32; FEATS];
        loop_features(&n, 0, &mut f);
        assert_eq!(f[4..].iter().sum::<f32>(), 2.0, "{f:?}");

        // mlp write-back n loop: T, bias, C all unit-stride -> bin 0 = 3.
        let n = Nest::initial(Problem::mlp(32, 64, 128));
        let wb_n = n.loops.len() - 1;
        let mut f = [0.0f32; FEATS];
        loop_features(&n, wb_n, &mut f);
        assert_eq!(f[4], 3.0, "{f:?}");
    }

    use crate::ir::Kind;

    #[test]
    fn feature_mask_zeroes_groups() {
        let n = nest();
        let full = state_vector(&n);
        let mut v = full.clone();
        FeatureMask { hist: false, ..Default::default() }.apply(&mut v);
        for (i, chunk) in v.chunks(FEATS).enumerate() {
            assert!(chunk[4..].iter().all(|&x| x == 0.0), "loop {i}");
            // Non-hist features preserved.
            assert_eq!(chunk[..4], full[i * FEATS..i * FEATS + 4]);
        }
        let mut v = full.clone();
        FeatureMask { cursor: false, ..Default::default() }.apply(&mut v);
        assert!(v.chunks(FEATS).all(|c| c[0] == 0.0));

        let mut v = full.clone();
        FeatureMask::default().apply(&mut v);
        assert_eq!(v, full, "default mask is identity");
    }
}
