//! LoopTune — RL-driven loop-schedule auto-tuning for tensor contractions.
//!
//! Reproduction of *LoopTune: Optimizing Tensor Computations with
//! Reinforcement Learning* (Grubisic et al., 2023) as a three-layer stack:
//!
//! - **L3 (this crate)**: the coordinator — a generalized loop-nest IR
//!   ("LoopTool") over arbitrary tensor contractions (named dims +
//!   per-tensor access maps; matmul, batched matmul, convolutions and MLP
//!   layers are constructors, see [`ir::Problem`] and `eval::workloads`),
//!   cursor-based action space, graph-derived state featurizer, the
//!   "LoopNest" backend substrate (schedule executor + analytical cost
//!   model + empirical peak), classical searches, RL trainers, simulated
//!   baselines, and the evaluation harness for every table/figure.
//! - **L2 (python/compile/model.py)**: Q-/policy-networks and their
//!   training steps, AOT-lowered to HLO text once at build time.
//! - **L1 (python/compile/kernels/)**: Pallas fused-linear kernel inside
//!   every dense layer of L2.
//!
//! Python never runs at tuning/training time: [`runtime`] loads the AOT
//! artifacts via PJRT and the trainers in [`rl`] drive them from Rust.
//!
//! Schedule evaluation is concurrent end-to-end: [`backend::SharedBackend`]
//! is a `Send + Sync` handle over a lock-striped eval cache and a pool of
//! backend instances, [`search`] scores candidate actions from worker
//! threads, and [`search::batch`] (the `tune-many` subcommand) fans whole
//! problem sets across a scoped thread pool. See DESIGN.md §6 and
//! README.md for the architecture and reproduction commands.
//!
//! The crate's front door is [`api`] (DESIGN.md §9): every tuner — policy
//! rollout, classical search, simulated baseline — implements the one
//! [`api::Strategy`] trait, typed [`api::TuneRequest`] /
//! [`api::TuneResponse`] messages (JSON-codable) describe jobs, and
//! [`api::TuningService`] serves them over warm cross-request state (the
//! shared backend pool, loaded policies, the measured peak). The CLI
//! subcommands are thin adapters over it.
//!
//! [`store`] (DESIGN.md §10) is the serving system's memory: every
//! completed tune is persisted as a `tune_record/v2` JSONL line (v1
//! lines still decode with a default-machine fallback), repeat traffic
//! for an exact problem is served from the store with zero backend
//! evaluations, cold misses can be transfer-tuned by replaying the
//! nearest recorded schedules, and a learned cost ranker trained from
//! the corpus pre-orders search expansion.
//!
//! [`machine`] (DESIGN.md §15) makes the hardware a first-class entity:
//! a serializable [`machine::MachineDescriptor`] with a stable
//! fingerprint is stamped into every record, threaded through requests,
//! responses, and serve metrics, and drives machine-aware transfer
//! distances plus per-machine cost-ranker heads — the continual-learning
//! eval (`eval machine`) shows warm cross-machine transfer beating cold
//! tuning on a simulated new machine.
//!
//! [`graph`] (DESIGN.md §14) lifts tuning from kernels to whole models:
//! a multi-op graph IR of [`ir::Problem`] nodes wired through named
//! tensors, an epilogue-fusion rewrite folding elementwise ops into
//! contraction write-backs, a graph-level tuner apportioning one budget
//! across nodes with store-backed schedule reuse, and a compiled
//! back-to-back executor with intermediate-buffer reuse.

#![warn(missing_docs)]

pub mod api;
pub mod backend;
pub mod baselines;
pub mod config;
pub mod dataset;
pub mod env;
pub mod eval;
pub mod featurize;
pub mod graph;
pub mod ir;
pub mod machine;
pub mod rl;
pub mod runtime;
pub mod search;
pub mod store;
pub mod util;

pub use env::actions::{Action, NUM_ACTIONS};
pub use env::Env;
pub use ir::{Nest, Problem, MAX_LOOPS};

/// Features per loop in the state vector (paper §III-C).
pub const FEATS: usize = 20;
/// Flattened state dimension fed to the networks.
pub const STATE_DIM: usize = ir::MAX_LOOPS * FEATS;
