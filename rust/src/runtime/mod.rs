//! PJRT runtime — loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json` produced by `python/compile/aot.py`) and executes them.
//!
//! HLO *text* is the interchange format (xla_extension 0.5.1 rejects the
//! 64-bit instruction ids of jax>=0.5 serialized protos; the text parser
//! reassigns ids — see /opt/xla-example/README.md). Executables compile
//! lazily on first use and are cached for the life of the process; Python
//! never runs at tuning/training time.

pub mod literal;

// The `xla` bindings are feature-gated: the default build carries no
// external dependency and compiles the API-compatible offline stub, so the
// whole crate (including the RL trainers that type against `xla::Literal`)
// builds and unit-tests without the native library. `--features pjrt`
// re-exports the real crate under the same `runtime::xla` path instead
// (DESIGN.md §7).
#[cfg(not(feature = "pjrt"))]
#[path = "xla_stub.rs"]
pub mod xla;
#[cfg(feature = "pjrt")]
pub use ::xla;

use self::xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::util::json::{self, Json};

/// Element type of an artifact input.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

/// Ordered input signature entry.
#[derive(Clone, Debug)]
pub struct InputSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

/// One AOT entry point.
#[derive(Clone, Debug)]
pub struct EntrySpec {
    pub file: String,
    pub inputs: Vec<InputSpec>,
    pub num_outputs: usize,
}

/// Shape constants shared with python/compile/model.py.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Constants {
    pub max_loops: usize,
    pub feats: usize,
    pub state_dim: usize,
    pub num_actions: usize,
    pub hidden: usize,
    pub batch: usize,
}

pub struct Runtime {
    client: PjRtClient,
    dir: PathBuf,
    pub constants: Constants,
    entries: HashMap<String, EntrySpec>,
    /// Lazily compiled executables, behind a mutex so one warm `Runtime`
    /// can be shared across the tuning service's worker threads (compiles
    /// serialize; a key is compiled at most once).
    exes: Mutex<HashMap<String, Arc<PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Load the manifest and start a CPU PJRT client. Cheap: executables
    /// compile lazily per entry point.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} (run `make artifacts`)"))?;
        let doc = json::parse(&text).map_err(|e| anyhow!("{e}"))?;

        let cs = doc.get("constants").ok_or_else(|| anyhow!("missing constants"))?;
        let get = |k: &str| -> Result<usize> {
            cs.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("missing constant {k}"))
        };
        let constants = Constants {
            max_loops: get("max_loops")?,
            feats: get("feats")?,
            state_dim: get("state_dim")?,
            num_actions: get("num_actions")?,
            hidden: get("hidden")?,
            batch: get("batch")?,
        };
        // The rust coordinator and the compiled networks must agree.
        if constants.max_loops != crate::ir::MAX_LOOPS
            || constants.feats != crate::FEATS
            || constants.state_dim != crate::STATE_DIM
            || constants.num_actions != crate::NUM_ACTIONS
        {
            bail!(
                "manifest constants {constants:?} disagree with crate constants \
                 (MAX_LOOPS={}, FEATS={}, STATE_DIM={}, NUM_ACTIONS={}) — \
                 rebuild artifacts",
                crate::ir::MAX_LOOPS,
                crate::FEATS,
                crate::STATE_DIM,
                crate::NUM_ACTIONS
            );
        }

        let mut entries = HashMap::new();
        let ents = doc
            .get("entries")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("missing entries"))?;
        for (name, e) in ents {
            let file = e
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("{name}: missing file"))?
                .to_string();
            let num_outputs = e
                .get("num_outputs")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("{name}: missing num_outputs"))?;
            let mut inputs = Vec::new();
            for inp in e
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("{name}: missing inputs"))?
            {
                let shape = inp
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("{name}: bad shape"))?
                    .iter()
                    .map(|d| d.as_usize().unwrap_or(0))
                    .collect();
                let dtype = match inp.get("dtype").and_then(Json::as_str) {
                    Some("float32") => DType::F32,
                    Some("int32") => DType::I32,
                    other => bail!("{name}: unsupported dtype {other:?}"),
                };
                inputs.push(InputSpec { shape, dtype });
            }
            entries.insert(name.clone(), EntrySpec { file, inputs, num_outputs });
        }

        let client = PjRtClient::cpu().context("starting PJRT CPU client")?;
        Ok(Runtime {
            client,
            dir,
            constants,
            entries,
            exes: Mutex::new(HashMap::new()),
        })
    }

    /// Default artifacts dir: `$LOOPTUNE_ARTIFACTS` or `./artifacts`.
    pub fn load_default() -> Result<Self> {
        let dir = std::env::var("LOOPTUNE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::load(dir)
    }

    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow!("unknown entry point {name}"))
    }

    pub fn entry_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.entries.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    fn executable(&self, name: &str) -> Result<Arc<PjRtLoadedExecutable>> {
        let mut exes = self.exes.lock().expect("executable cache poisoned");
        if let Some(exe) = exes.get(name) {
            return Ok(exe.clone());
        }
        let spec = self.entry(name)?;
        let path = self.dir.join(&spec.file);
        let proto = HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?,
        );
        exes.insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an entry point. Inputs must match the manifest signature in
    /// count. Returns the flattened output tuple. Accepts owned Literals or
    /// references, so hot paths can keep cached param Literals and avoid
    /// re-marshalling (see rl::dqn §Perf).
    pub fn exec<L: std::borrow::Borrow<Literal>>(
        &self,
        name: &str,
        args: &[L],
    ) -> Result<Vec<Literal>> {
        let spec = self.entry(name)?;
        if args.len() != spec.inputs.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                spec.inputs.len(),
                args.len()
            );
        }
        let exe = self.executable(name)?;
        let result = exe.execute::<L>(args)?;
        let lit = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let outs = lit.to_tuple()?;
        if outs.len() != spec.num_outputs {
            bail!(
                "{name}: expected {} outputs, got {}",
                spec.num_outputs,
                outs.len()
            );
        }
        Ok(outs)
    }

    /// Compile an entry point from scratch (no cache), returning the
    /// wall-clock compile time — the Table I comparator measurement.
    pub fn time_compile(&self, name: &str) -> Result<Duration> {
        let spec = self.entry(name)?;
        let path = self.dir.join(&spec.file);
        let proto = HloModuleProto::from_text_file(path.to_str().unwrap())?;
        let comp = XlaComputation::from_proto(&proto);
        let t0 = Instant::now();
        let exe = self.client.compile(&comp)?;
        let dt = t0.elapsed();
        drop(exe);
        Ok(dt)
    }

    /// Whether PJRT execution is possible here: the crate was built with
    /// the `pjrt` feature **and** the artifacts directory looks usable.
    /// Integration tests and benches gate on this and skip with a note.
    pub fn available(dir: impl AsRef<Path>) -> bool {
        cfg!(feature = "pjrt") && dir.as_ref().join("manifest.json").exists()
    }
}
