//! Offline stand-in for the `xla` (PJRT) bindings, compiled when the
//! `pjrt` feature is **off** (the default).
//!
//! The stub keeps the whole `runtime`/`rl` stack compiling and unit-testable
//! without the native `xla_extension` library: the pure marshalling surface
//! ([`Literal`] construction, reshape, host round-trips) is implemented for
//! real, while anything that would need a PJRT client ([`PjRtClient::cpu`],
//! compilation, execution) returns a descriptive error at runtime. Builds
//! with `--features pjrt` re-export the real crate instead (DESIGN.md §7).

use std::borrow::Borrow;
use std::fmt;

/// Error type mirroring the real bindings' error.
#[derive(Clone, Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Result alias matching the real bindings.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT is unavailable — looptune was built without the \
         `pjrt` feature (rebuild with `--features pjrt` and the xla \
         bindings crate, see DESIGN.md §7)"
    ))
}

/// Element types the stub can marshal (the subset looptune uses).
pub trait NativeType: Copy {
    /// Wrap host data into literal storage.
    fn wrap(data: Vec<Self>) -> Data;
    /// Extract host data from a literal, failing on a dtype mismatch.
    fn unwrap(lit: &Literal) -> Result<Vec<Self>>;
}

/// Literal storage: typed flat buffers or a tuple of literals.
#[derive(Clone, Debug, PartialEq)]
pub enum Data {
    /// 32-bit float elements.
    F32(Vec<f32>),
    /// 32-bit integer elements.
    I32(Vec<i32>),
    /// A tuple of nested literals.
    Tuple(Vec<Literal>),
}

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> Data {
        Data::F32(data)
    }
    fn unwrap(lit: &Literal) -> Result<Vec<f32>> {
        match &lit.data {
            Data::F32(v) => Ok(v.clone()),
            _ => Err(Error("literal is not f32".into())),
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>) -> Data {
        Data::I32(data)
    }
    fn unwrap(lit: &Literal) -> Result<Vec<i32>> {
        match &lit.data {
            Data::I32(v) => Ok(v.clone()),
            _ => Err(Error("literal is not i32".into())),
        }
    }
}

/// Host-side literal: shape dims + typed storage.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: T::wrap(data.to_vec()) }
    }

    fn element_count(&self) -> i64 {
        match &self.data {
            Data::F32(v) => v.len() as i64,
            Data::I32(v) => v.len() as i64,
            Data::Tuple(_) => 0,
        }
    }

    /// Reinterpret the element buffer under new dims (element count must
    /// match; `&[]` produces a scalar).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = self.element_count();
        if want != have {
            return Err(Error(format!(
                "reshape: cannot view {have} elements as {dims:?}"
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    /// The array shape (dims) of a non-tuple literal.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self.data {
            Data::Tuple(_) => Err(Error("tuple literal has no array shape".into())),
            _ => Ok(ArrayShape { dims: self.dims.clone() }),
        }
    }

    /// Copy the elements out to a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(self)
    }

    /// Decompose a tuple literal into its members.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            Data::Tuple(v) => Ok(v),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }
}

/// Array shape of a literal (dims only — looptune is f32/i32-typed).
#[derive(Clone, Debug, PartialEq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    /// Dimension extents.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (opaque in the stub).
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parsing HLO text requires the real bindings.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation handle (opaque in the stub).
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed module (never reached in the stub: parsing fails first).
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client handle.
pub struct PjRtClient;

impl PjRtClient {
    /// Starting a CPU client requires the real bindings.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    /// Compiling requires the real bindings.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Executing requires the real bindings.
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Device-to-host transfer requires the real bindings.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec1_reshape_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let m = l.reshape(&[2, 3]).unwrap();
        assert_eq!(m.array_shape().unwrap().dims(), &[2, 3]);
        let back: Vec<f32> = m.to_vec().unwrap();
        assert_eq!(back, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn scalar_reshape() {
        let l = Literal::vec1(&[7i32]).reshape(&[]).unwrap();
        assert!(l.array_shape().unwrap().dims().is_empty());
        let v: Vec<i32> = l.to_vec().unwrap();
        assert_eq!(v, vec![7]);
    }

    #[test]
    fn reshape_rejects_bad_count() {
        assert!(Literal::vec1(&[1.0f32, 2.0]).reshape(&[3]).is_err());
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let l = Literal::vec1(&[1i32, 2]);
        assert!(l.to_vec::<f32>().is_err());
    }

    #[test]
    fn pjrt_paths_error_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("pjrt"), "{msg}");
    }
}
