//! Host-side tensor type + Literal marshalling helpers.

use crate::runtime::xla::Literal;
use anyhow::{bail, Result};

/// A host tensor: shape + row-major f32 data. The unit the trainers and
/// the param store operate on; marshalled to/from `xla::Literal` at the
/// PJRT boundary.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        HostTensor { shape, data: vec![0.0; n] }
    }

    pub fn scalar(x: f32) -> Self {
        HostTensor { shape: vec![], data: vec![x] }
    }

    pub fn to_literal(&self) -> Result<Literal> {
        let v = Literal::vec1(&self.data);
        if self.shape.is_empty() {
            // A true scalar literal (vec1 of len 1 reshaped to rank 0).
            Ok(v.reshape(&[])?)
        } else {
            let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
            Ok(v.reshape(&dims)?)
        }
    }

    pub fn from_literal(lit: &Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data: Vec<f32> = lit.to_vec()?;
        Ok(HostTensor::new(dims, data))
    }
}

/// f32 literal from raw parts.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims)?)
}

/// i32 literal from raw parts.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims)?)
}

/// Scalar literals.
pub fn lit_f32_scalar(x: f32) -> Result<Literal> {
    Ok(Literal::vec1(&[x]).reshape(&[])?)
}

pub fn lit_i32_scalar(x: i32) -> Result<Literal> {
    Ok(Literal::vec1(&[x]).reshape(&[])?)
}

/// Extract a scalar f32 from a literal (rank 0 or single element).
pub fn scalar_f32(lit: &Literal) -> Result<f32> {
    let v: Vec<f32> = lit.to_vec()?;
    if v.len() != 1 {
        bail!("expected scalar, got {} elements", v.len());
    }
    Ok(v[0])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_roundtrip() {
        let t = HostTensor::new(vec![2, 3], (0..6).map(|x| x as f32).collect());
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn scalar_roundtrip() {
        let t = HostTensor::scalar(3.5);
        let lit = t.to_literal().unwrap();
        assert_eq!(scalar_f32(&lit).unwrap(), 3.5);
        let back = HostTensor::from_literal(&lit).unwrap();
        assert!(back.shape.is_empty());
        assert_eq!(back.data, vec![3.5]);
    }

    #[test]
    fn zeros_shape() {
        let t = HostTensor::zeros(vec![4, 5]);
        assert_eq!(t.data.len(), 20);
        assert!(t.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn i32_literal() {
        let lit = lit_i32(&[1, 2, 3], &[3]).unwrap();
        let v: Vec<i32> = lit.to_vec().unwrap();
        assert_eq!(v, vec![1, 2, 3]);
    }
}
