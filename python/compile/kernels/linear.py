"""L1 — Pallas fused linear kernel (matmul + bias + optional ReLU).

This is the compute hot-spot of every network in the LoopTune stack
(Q-network, policy/value networks): each dense layer lowers to one
`pallas_call`. The kernel is a classic blocked matmul:

  grid = (M/bm, N/bn, K/bk), K innermost; partial products accumulate in
  the resident output tile (its block index is independent of k, so the
  tile stays live across the K loop); bias-add + activation fuse into the
  final K-step write-back.

`interpret=True` always: the CPU PJRT plugin cannot run Mosaic
custom-calls, and the whole stack (including the rust coordinator) runs on
CPU. On a real TPU the same BlockSpec schedule maps the (bm, bk) x (bk, bn)
tile product onto the MXU — see DESIGN.md §9 for the VMEM/MXU estimate.

Backward pass: `linear` carries a custom VJP whose dx/dw matmuls reuse the
same Pallas kernel, so the AOT-lowered training steps contain Pallas-derived
HLO on both the forward and backward paths.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block shapes. Small enough to keep interpret-mode overhead sane
# on CPU, MXU-friendly (multiples of 8 / 64) on TPU.
BM, BN, BK = 16, 64, 64


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _matmul_bias_kernel(x_ref, w_ref, b_ref, o_ref, *, nk: int, relu: bool):
    """One (i, j, k) grid step: o += x_tile @ w_tile; finalize at k==nk-1."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _finalize():
        y = o_ref[...] + b_ref[...][None, :]
        if relu:
            y = jnp.maximum(y, 0.0)
        o_ref[...] = y


def _linear_impl(x, w, b, relu: bool, bm: int = BM, bn: int = BN, bk: int = BK):
    """Padded blocked Pallas matmul: y = act(x @ w + b)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"shape mismatch {x.shape} @ {w.shape}"
    assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"

    bm, bn, bk = min(bm, _ceil_to(m, 8)), min(bn, _ceil_to(n, 8)), min(bk, _ceil_to(k, 8))
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    bp = jnp.pad(b, (0, np_ - n))
    nk = kp // bk

    out = pl.pallas_call(
        functools.partial(_matmul_bias_kernel, nk=nk, relu=relu),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def linear(x, w, b, relu: bool = False):
    """y = relu?(x @ w + b) via the Pallas blocked kernel.

    x: (M, K) f32, w: (K, N) f32, b: (N,) f32 -> (M, N) f32.
    Differentiable in x, w, b; the VJP reuses the Pallas kernel.
    """
    return _linear_impl(x, w, b, relu)


def _linear_fwd(x, w, b, relu: bool):
    y = _linear_impl(x, w, b, relu)
    return y, (x, w, y if relu else None)


def _linear_bwd(relu: bool, res, g):
    x, w, y = res
    if relu:
        g = g * (y > 0.0).astype(g.dtype)
    zk = jnp.zeros((w.shape[0],), jnp.float32)
    zn = jnp.zeros((w.shape[1],), jnp.float32)
    # dx = g @ w.T ; dw = x.T @ g — same Pallas kernel, zero bias, no act.
    dx = _linear_impl(g, w.T, zk, relu=False)
    dw = _linear_impl(x.T, g, zn, relu=False)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


linear.defvjp(_linear_fwd, _linear_bwd)
