"""Pure-jnp oracles for the Pallas kernels (correctness references).

Every Pallas kernel in this package has an oracle here; pytest + hypothesis
sweep shapes/values and assert_allclose kernel vs oracle (see
python/tests/test_kernel.py).
"""

import jax.numpy as jnp


def linear_ref(x, w, b, relu: bool = False):
    """y = act(x @ w + b), plain jnp."""
    y = jnp.matmul(x, w, preferred_element_type=jnp.float32) + b[None, :]
    if relu:
        y = jnp.maximum(y, 0.0)
    return y


def mlp3_ref(params, x):
    """Three-layer MLP oracle matching model.q_forward."""
    w1, b1, w2, b2, w3, b3 = params
    h = linear_ref(x, w1, b1, relu=True)
    h = linear_ref(h, w2, b2, relu=True)
    return linear_ref(h, w3, b3, relu=False)
