"""L2 — JAX compute graphs for the LoopTune stack (build-time only).

Defines the Q-network / policy-value network used by the rust coordinator,
their parameter initializers, and the compiled *training steps* (double-DQN,
PPO, A2C — IMPALA reuses the A2C step with V-trace targets computed by the
coordinator). Every dense layer goes through the L1 Pallas kernel
(`kernels.linear.linear`), so the lowered HLO carries Pallas-derived compute
on both the forward and backward paths.

All functions here take/return *flat tuples of arrays* in a fixed positional
order — the same order the rust runtime marshals Literals in. aot.py lowers
each entry point once to HLO text + records the signature in
artifacts/manifest.json. Python never runs at training/inference time.

Shape constants must match rust/src/featurize (MAX_LOOPS * FEATS = STATE_DIM)
and rust/src/env/actions.rs (NUM_ACTIONS).
"""

import jax
import jax.numpy as jnp

from .kernels.linear import linear

MAX_LOOPS = 10
FEATS = 20
STATE_DIM = MAX_LOOPS * FEATS  # 200
# Contract v2: parallelize appended at index 10 (indices 0-9 unchanged).
NUM_ACTIONS = 11  # up, down, swap_up, swap_down, split{2,4,8,16,32,64}, parallelize
HIDDEN = 256
BATCH = 64

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8
HUBER_DELTA = 1.0

# ---------------------------------------------------------------------------
# Networks
# ---------------------------------------------------------------------------

Q_SHAPES = [
    (STATE_DIM, HIDDEN), (HIDDEN,),
    (HIDDEN, HIDDEN), (HIDDEN,),
    (HIDDEN, NUM_ACTIONS), (NUM_ACTIONS,),
]

# Shared trunk + policy head + value head.
PV_SHAPES = [
    (STATE_DIM, HIDDEN), (HIDDEN,),
    (HIDDEN, HIDDEN), (HIDDEN,),
    (HIDDEN, NUM_ACTIONS), (NUM_ACTIONS,),  # policy head
    (HIDDEN, 1), (1,),  # value head
]


def q_forward(params, s):
    """Q(s, ·): (B, STATE_DIM) -> (B, NUM_ACTIONS)."""
    w1, b1, w2, b2, w3, b3 = params
    h = linear(s, w1, b1, True)
    h = linear(h, w2, b2, True)
    return linear(h, w3, b3, False)


def pv_forward(params, s):
    """Policy logits + state value: (B, S) -> ((B, A), (B,))."""
    w1, b1, w2, b2, wp, bp, wv, bv = params
    h = linear(s, w1, b1, True)
    h = linear(h, w2, b2, True)
    logits = linear(h, wp, bp, False)
    value = linear(h, wv, bv, False)[:, 0]
    return logits, value


def _he_init(key, shapes):
    params = []
    for shape in shapes:
        key, sub = jax.random.split(key)
        if len(shape) == 2:
            fan_in = shape[0]
            params.append(
                jax.random.normal(sub, shape, jnp.float32)
                * jnp.sqrt(2.0 / fan_in)
            )
        else:
            params.append(jnp.zeros(shape, jnp.float32))
    return tuple(params)


def q_init(seed):
    """seed: i32[] -> 6 Q-net params (He init, zero biases)."""
    return _he_init(jax.random.PRNGKey(seed), Q_SHAPES)


def pv_init(seed):
    """seed: i32[] -> 8 policy/value params."""
    return _he_init(jax.random.PRNGKey(seed), PV_SHAPES)


# ---------------------------------------------------------------------------
# Adam
# ---------------------------------------------------------------------------


def adam_update(params, grads, m, v, step, lr):
    """One Adam step over flat tuples. step is the *previous* count (f32[])."""
    t = step + 1.0
    new_m = tuple(ADAM_B1 * mi + (1 - ADAM_B1) * g for mi, g in zip(m, grads))
    new_v = tuple(ADAM_B2 * vi + (1 - ADAM_B2) * g * g for vi, g in zip(v, grads))
    mc = 1.0 - ADAM_B1 ** t
    vc = 1.0 - ADAM_B2 ** t
    new_p = tuple(
        p - lr * (mi / mc) / (jnp.sqrt(vi / vc) + ADAM_EPS)
        for p, mi, vi in zip(params, new_m, new_v)
    )
    return new_p, new_m, new_v, t


def _clip_by_global_norm(grads, max_norm=10.0):
    gn = jnp.sqrt(sum(jnp.sum(g * g) for g in grads))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return tuple(g * scale for g in grads), gn


# ---------------------------------------------------------------------------
# DQN (double-DQN + Huber + prioritized-replay importance weights)
# ---------------------------------------------------------------------------


def _huber(x):
    ax = jnp.abs(x)
    return jnp.where(
        ax <= HUBER_DELTA, 0.5 * x * x, HUBER_DELTA * (ax - 0.5 * HUBER_DELTA)
    )


def dqn_loss(params, target_params, s, a, r, s2, done, weights, gamma):
    q = q_forward(params, s)
    qa = jnp.take_along_axis(q, a[:, None], axis=1)[:, 0]
    a2 = jnp.argmax(q_forward(params, s2), axis=1)
    q2 = q_forward(target_params, s2)
    q2a = jnp.take_along_axis(q2, a2[:, None], axis=1)[:, 0]
    target = r + gamma * (1.0 - done) * jax.lax.stop_gradient(q2a)
    td = qa - jax.lax.stop_gradient(target)
    loss = jnp.mean(weights * _huber(td))
    return loss, jnp.abs(td)


def dqn_train_step(
    w1, b1, w2, b2, w3, b3,
    tw1, tb1, tw2, tb2, tw3, tb3,
    m1, m2, m3, m4, m5, m6,
    v1, v2, v3, v4, v5, v6,
    step, s, a, r, s2, done, weights, lr, gamma,
):
    """One double-DQN step. Returns (6 params, 6 m, 6 v, step', |td| [B], loss)."""
    params = (w1, b1, w2, b2, w3, b3)
    tparams = (tw1, tb1, tw2, tb2, tw3, tb3)
    m = (m1, m2, m3, m4, m5, m6)
    v = (v1, v2, v3, v4, v5, v6)
    (loss, td_abs), grads = jax.value_and_grad(dqn_loss, has_aux=True)(
        params, tparams, s, a, r, s2, done, weights, gamma
    )
    grads, _ = _clip_by_global_norm(grads)
    new_p, new_m, new_v, t = adam_update(params, grads, m, v, step, lr)
    return (*new_p, *new_m, *new_v, t, td_abs, loss)


# ---------------------------------------------------------------------------
# PPO (clipped surrogate + value loss + entropy bonus)
# ---------------------------------------------------------------------------


def ppo_loss(params, s, a, adv, ret, old_logp, clip_eps, ent_coef):
    logits, value = pv_forward(params, s)
    logp_all = jax.nn.log_softmax(logits, axis=1)
    logp = jnp.take_along_axis(logp_all, a[:, None], axis=1)[:, 0]
    ratio = jnp.exp(logp - old_logp)
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps)
    pg = -jnp.mean(jnp.minimum(ratio * adv, clipped * adv))
    vloss = 0.5 * jnp.mean((value - ret) ** 2)
    ent = -jnp.mean(jnp.sum(jnp.exp(logp_all) * logp_all, axis=1))
    loss = pg + 0.5 * vloss - ent_coef * ent
    approx_kl = jnp.mean(old_logp - logp)
    return loss, (approx_kl, ent)


def ppo_train_step(
    w1, b1, w2, b2, wp, bp, wv, bv,
    m1, m2, m3, m4, m5, m6, m7, m8,
    v1, v2, v3, v4, v5, v6, v7, v8,
    step, s, a, adv, ret, old_logp, lr, clip_eps, ent_coef,
):
    """One PPO minibatch step. Returns (8 params, 8 m, 8 v, step', loss, kl, ent)."""
    params = (w1, b1, w2, b2, wp, bp, wv, bv)
    m = (m1, m2, m3, m4, m5, m6, m7, m8)
    v = (v1, v2, v3, v4, v5, v6, v7, v8)
    (loss, (kl, ent)), grads = jax.value_and_grad(ppo_loss, has_aux=True)(
        params, s, a, adv, ret, old_logp, clip_eps, ent_coef
    )
    grads, _ = _clip_by_global_norm(grads)
    new_p, new_m, new_v, t = adam_update(params, grads, m, v, step, lr)
    return (*new_p, *new_m, *new_v, t, loss, kl, ent)


# ---------------------------------------------------------------------------
# A2C (sync A3C). IMPALA reuses this step: the coordinator computes V-trace
# corrected advantages/returns (rho/c clipped) and feeds them as adv/ret.
# ---------------------------------------------------------------------------


def a2c_loss(params, s, a, adv, ret, ent_coef):
    logits, value = pv_forward(params, s)
    logp_all = jax.nn.log_softmax(logits, axis=1)
    logp = jnp.take_along_axis(logp_all, a[:, None], axis=1)[:, 0]
    pg = -jnp.mean(logp * adv)
    vloss = 0.5 * jnp.mean((value - ret) ** 2)
    ent = -jnp.mean(jnp.sum(jnp.exp(logp_all) * logp_all, axis=1))
    loss = pg + 0.5 * vloss - ent_coef * ent
    return loss, ent


def a2c_train_step(
    w1, b1, w2, b2, wp, bp, wv, bv,
    m1, m2, m3, m4, m5, m6, m7, m8,
    v1, v2, v3, v4, v5, v6, v7, v8,
    step, s, a, adv, ret, lr, ent_coef,
):
    """One A2C step. Returns (8 params, 8 m, 8 v, step', loss, ent)."""
    params = (w1, b1, w2, b2, wp, bp, wv, bv)
    m = (m1, m2, m3, m4, m5, m6, m7, m8)
    v = (v1, v2, v3, v4, v5, v6, v7, v8)
    (loss, ent), grads = jax.value_and_grad(a2c_loss, has_aux=True)(
        params, s, a, adv, ret, ent_coef
    )
    grads, _ = _clip_by_global_norm(grads)
    new_p, new_m, new_v, t = adam_update(params, grads, m, v, step, lr)
    return (*new_p, *new_m, *new_v, t, loss, ent)


# ---------------------------------------------------------------------------
# Plain matmuls for the Table I XLA-compile comparator
# ---------------------------------------------------------------------------


def matmul(x, y):
    return jnp.matmul(x, y)
