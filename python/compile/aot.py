"""AOT compiler: lower every L2 entry point to HLO *text* + manifest.json.

HLO text (NOT `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the rust `xla` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/load_hlo/.

Usage:  cd python && python -m compile.aot --out ../artifacts

Everything is lowered with return_tuple=True; the rust runtime unwraps the
tuple. artifacts/manifest.json records, for each entry point, the ordered
input signature (shape, dtype) and output arity, plus the shape constants
shared with the rust coordinator.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32, I32 = jnp.float32, jnp.int32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def q_specs():
    return [spec(s) for s in model.Q_SHAPES]


def pv_specs():
    return [spec(s) for s in model.PV_SHAPES]


def entry_points():
    """name -> (callable, [input specs])."""
    S, A, B = model.STATE_DIM, model.NUM_ACTIONS, model.BATCH
    eps = {}

    eps["q_init"] = (lambda seed: model.q_init(seed), [spec((), I32)])
    eps["pv_init"] = (lambda seed: model.pv_init(seed), [spec((), I32)])

    for b in (1, B):
        eps[f"q_forward_b{b}"] = (
            lambda *a, _b=b: model.q_forward(a[:6], a[6]),
            q_specs() + [spec((b, S))],
        )
    eps["pv_forward_b1"] = (
        lambda *a: model.pv_forward(a[:8], a[8]),
        pv_specs() + [spec((1, S))],
    )

    eps["dqn_train_step"] = (
        model.dqn_train_step,
        q_specs() * 4  # params, target params, adam m, adam v
        + [
            spec(()),               # step
            spec((B, S)),           # s
            spec((B,), I32),        # a
            spec((B,)),             # r
            spec((B, S)),           # s2
            spec((B,)),             # done
            spec((B,)),             # weights
            spec(()),               # lr
            spec(()),               # gamma
        ],
    )
    eps["ppo_train_step"] = (
        model.ppo_train_step,
        pv_specs() * 3
        + [
            spec(()),               # step
            spec((B, S)),           # s
            spec((B,), I32),        # a
            spec((B,)),             # adv
            spec((B,)),             # ret
            spec((B,)),             # old_logp
            spec(()),               # lr
            spec(()),               # clip_eps
            spec(()),               # ent_coef
        ],
    )
    eps["a2c_train_step"] = (
        model.a2c_train_step,
        pv_specs() * 3
        + [
            spec(()),               # step
            spec((B, S)),           # s
            spec((B,), I32),        # a
            spec((B,)),             # adv
            spec((B,)),             # ret
            spec(()),               # lr
            spec(()),               # ent_coef
        ],
    )

    # Plain matmuls: the Table I "traditional compiler" comparator measures
    # PJRT compile time + execution GFLOPS of these from rust.
    for n in (64, 128, 256, 512):
        eps[f"mm_{n}"] = (model.matmul, [spec((n, n)), spec((n, n))])

    return eps


def num_outputs(fn, in_specs):
    out = jax.eval_shape(fn, *in_specs)
    return len(out) if isinstance(out, (tuple, list)) else 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifacts dir")
    ap.add_argument("--only", default=None, help="comma-separated entry names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    eps = entry_points()
    only = set(args.only.split(",")) if args.only else None
    manifest = {
        "constants": {
            "max_loops": model.MAX_LOOPS,
            "feats": model.FEATS,
            "state_dim": model.STATE_DIM,
            "num_actions": model.NUM_ACTIONS,
            "hidden": model.HIDDEN,
            "batch": model.BATCH,
        },
        "entries": {},
    }
    for name, (fn, in_specs) in eps.items():
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        manifest["entries"][name] = {
            "file": fname,
            "inputs": [
                {"shape": list(s.shape), "dtype": str(s.dtype)} for s in in_specs
            ],
            "num_outputs": num_outputs(fn, in_specs),
        }
        print(f"  {name}: {len(text)} chars, {len(in_specs)} inputs, "
              f"{manifest['entries'][name]['num_outputs']} outputs")

    mpath = os.path.join(args.out, "manifest.json")
    # Merge with an existing manifest when --only is used.
    if only and os.path.exists(mpath):
        with open(mpath) as f:
            old = json.load(f)
        old["entries"].update(manifest["entries"])
        old["constants"] = manifest["constants"]
        manifest = old
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
