"""L1 correctness: Pallas fused-linear kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes and block sizes; every case asserts allclose
against ref.py for the forward pass and the custom VJP.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.linear import _linear_impl, linear
from compile.kernels.ref import linear_ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, *shape):
    return jax.random.normal(key, shape, jnp.float32)


def keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("relu", [False, True])
@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 200, 256),   # policy inference shape
        (64, 200, 256),  # training batch shape
        (64, 256, 10),   # output head
        (64, 256, 1),    # value head
        (3, 5, 7),       # tiny, nothing divides the blocks
        (16, 64, 64),    # exact block multiples
    ],
)
def test_forward_matches_ref(m, k, n, relu):
    k1, k2, k3 = keys(0, 3)
    x, w, b = rand(k1, m, k), rand(k2, k, n), rand(k3, n)
    got = linear(x, w, b, relu)
    want = linear_ref(x, w, b, relu)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 130),
    n=st.integers(1, 70),
    relu=st.booleans(),
    seed=st.integers(0, 2**30),
)
def test_forward_matches_ref_hypothesis(m, k, n, relu, seed):
    k1, k2, k3 = keys(seed, 3)
    x, w, b = rand(k1, m, k), rand(k2, k, n), rand(k3, n)
    got = linear(x, w, b, relu)
    want = linear_ref(x, w, b, relu)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


@settings(max_examples=10, deadline=None)
@given(
    bm=st.sampled_from([8, 16, 32]),
    bn=st.sampled_from([8, 64]),
    bk=st.sampled_from([8, 64]),
    seed=st.integers(0, 2**30),
)
def test_block_shape_invariance(bm, bn, bk, seed):
    """Any block configuration computes the same result."""
    k1, k2, k3 = keys(seed, 3)
    x, w, b = rand(k1, 33, 50), rand(k2, 50, 21), rand(k3, 21)
    got = _linear_impl(x, w, b, True, bm=bm, bn=bn, bk=bk)
    want = linear_ref(x, w, b, True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


def test_zero_and_identity_edge_cases():
    # Zero input -> bias only (+ relu clamp).
    x = jnp.zeros((4, 8))
    w = jnp.ones((8, 6))
    b = jnp.arange(-3.0, 3.0)
    got = linear(x, w, b, True)
    np.testing.assert_allclose(np.asarray(got), np.tile(np.maximum(np.arange(-3.0, 3.0), 0), (4, 1)))
    # Identity weights pass x through.
    x = rand(jax.random.PRNGKey(5), 7, 7)
    got = linear(x, jnp.eye(7), jnp.zeros(7), False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Backward (custom VJP through the Pallas kernel)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("relu", [False, True])
def test_vjp_matches_ref(relu):
    k1, k2, k3 = keys(1, 3)
    x, w, b = rand(k1, 9, 20), rand(k2, 20, 13), rand(k3, 13)

    def loss_kernel(x, w, b):
        return jnp.sum(jnp.tanh(linear(x, w, b, relu)))

    def loss_ref(x, w, b):
        return jnp.sum(jnp.tanh(linear_ref(x, w, b, relu)))

    g = jax.grad(loss_kernel, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
    for a, c in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**30), relu=st.booleans())
def test_vjp_matches_ref_hypothesis(seed, relu):
    k1, k2, k3, k4 = keys(seed, 4)
    x, w, b = rand(k1, 6, 11), rand(k2, 11, 5), rand(k3, 5)
    ct = rand(k4, 6, 5)

    _, vjp = jax.vjp(lambda x, w, b: linear(x, w, b, relu), x, w, b)
    _, vjp_ref = jax.vjp(lambda x, w, b: linear_ref(x, w, b, relu), x, w, b)
    for a, c in zip(vjp(ct), vjp_ref(ct)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=2e-4, atol=2e-4)


def test_relu_masks_gradient():
    # At a point where the pre-activation is negative, d/dx must be 0.
    x = -jnp.ones((1, 4))
    w = jnp.eye(4)
    b = jnp.zeros(4)
    g = jax.grad(lambda x: jnp.sum(linear(x, w, b, True)))(x)
    np.testing.assert_allclose(np.asarray(g), np.zeros((1, 4)))
