"""L2 correctness: networks, Adam, and the compiled training steps.

These run the same jitted functions aot.py lowers, so a green run here
plus the rust integration tests (which compare the compiled HLO against a
rust-side reference) validates the whole AOT path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels.ref import mlp3_ref

jax.config.update("jax_platform_name", "cpu")

B, S, A = model.BATCH, model.STATE_DIM, model.NUM_ACTIONS


def rand(seed, *shape):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


# ---------------------------------------------------------------------------
# Networks + init
# ---------------------------------------------------------------------------


def test_q_init_shapes_and_determinism():
    p = model.q_init(0)
    assert [tuple(t.shape) for t in p] == [tuple(s) for s in model.Q_SHAPES]
    p2 = model.q_init(0)
    for a, b in zip(p, p2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    p3 = model.q_init(1)
    assert not np.allclose(np.asarray(p[0]), np.asarray(p3[0]))
    # biases zero, weights he-scaled
    assert float(jnp.abs(p[1]).max()) == 0.0
    std = float(p[0].std())
    assert 0.5 * (2 / S) ** 0.5 < std < 2.0 * (2 / S) ** 0.5


def test_q_forward_matches_jnp_reference():
    p = model.q_init(3)
    x = rand(1, 5, S)
    got = model.q_forward(p, x)
    want = mlp3_ref(p, x)
    assert got.shape == (5, A)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_pv_forward_shapes():
    p = model.pv_init(4)
    x = rand(2, 3, S)
    logits, value = model.pv_forward(p, x)
    assert logits.shape == (3, A)
    assert value.shape == (3,)


# ---------------------------------------------------------------------------
# Adam
# ---------------------------------------------------------------------------


def test_adam_step_moves_against_gradient():
    params = (jnp.ones((4,)),)
    grads = (jnp.ones((4,)),)
    m = (jnp.zeros((4,)),)
    v = (jnp.zeros((4,)),)
    new_p, new_m, new_v, t = model.adam_update(params, grads, m, v, jnp.float32(0.0), 0.1)
    assert float(t) == 1.0
    # First Adam step with bias correction moves by ~lr.
    np.testing.assert_allclose(np.asarray(new_p[0]), 1.0 - 0.1, rtol=1e-3)
    assert float(new_m[0][0]) > 0.0
    assert float(new_v[0][0]) > 0.0


def test_clip_by_global_norm():
    big = (jnp.full((10,), 100.0),)
    clipped, gn = model._clip_by_global_norm(big, max_norm=10.0)
    assert float(gn) > 100.0
    norm = float(jnp.sqrt(sum(jnp.sum(g * g) for g in clipped)))
    np.testing.assert_allclose(norm, 10.0, rtol=1e-4)


# ---------------------------------------------------------------------------
# DQN training step
# ---------------------------------------------------------------------------


def dqn_args(seed=0):
    p = model.q_init(seed)
    tp = model.q_init(seed + 100)
    zeros = tuple(jnp.zeros_like(t) for t in p)
    key = jax.random.PRNGKey(seed + 7)
    s = jax.random.normal(key, (B, S), jnp.float32)
    a = jnp.zeros((B,), jnp.int32)
    r = jnp.ones((B,), jnp.float32)
    s2 = s + 0.1
    done = jnp.ones((B,), jnp.float32)
    w = jnp.ones((B,), jnp.float32)
    return (
        *p, *tp, *zeros, *zeros,
        jnp.float32(0.0), s, a, r, s2, done, w,
        jnp.float32(3e-3), jnp.float32(0.9),
    )


def test_dqn_train_step_reduces_loss_on_fixed_batch():
    args = list(dqn_args())
    step = jax.jit(model.dqn_train_step)
    losses = []
    for _ in range(8):
        out = step(*args)
        new_p, new_m, new_v, t = out[:6], out[6:12], out[12:18], out[18]
        td_abs, loss = out[19], out[20]
        assert td_abs.shape == (B,)
        losses.append(float(loss))
        args[0:6] = new_p
        args[12:18] = new_m
        args[18:24] = new_v
        args[24] = t
    assert losses[-1] < losses[0], losses
    assert all(np.isfinite(losses))


def test_dqn_importance_weights_scale_loss():
    args = list(dqn_args(1))
    out_w1 = model.dqn_train_step(*args)
    args[30] = jnp.full((B,), 0.5, jnp.float32)  # weights input
    out_w05 = model.dqn_train_step(*args)
    np.testing.assert_allclose(
        float(out_w05[20]), 0.5 * float(out_w1[20]), rtol=1e-4
    )


def test_dqn_done_masks_bootstrap():
    # With done=1 the target is just r; gamma must not matter.
    args = list(dqn_args(2))
    out_a = model.dqn_train_step(*args)
    args[32] = jnp.float32(0.0)  # gamma
    out_b = model.dqn_train_step(*args)
    np.testing.assert_allclose(float(out_a[20]), float(out_b[20]), rtol=1e-5)


# ---------------------------------------------------------------------------
# PPO / A2C training steps
# ---------------------------------------------------------------------------


def pv_zeros(p):
    return tuple(jnp.zeros_like(t) for t in p)


def test_ppo_train_step_improves_surrogate():
    p = model.pv_init(5)
    z = pv_zeros(p)
    key = jax.random.PRNGKey(11)
    s = jax.random.normal(key, (B, S), jnp.float32)
    a = jnp.zeros((B,), jnp.int32)
    adv = jnp.ones((B,), jnp.float32)  # action 0 is always advantageous
    logits, _ = model.pv_forward(p, s)
    old_logp = jax.nn.log_softmax(logits, axis=1)[:, 0]
    ret = jnp.zeros((B,), jnp.float32)

    args = [*p, *z, *z, jnp.float32(0.0), s, a, adv, ret, old_logp,
            jnp.float32(1e-2), jnp.float32(0.2), jnp.float32(0.0)]
    step = jax.jit(model.ppo_train_step)
    for _ in range(5):
        out = step(*args)
        args[0:8] = out[:8]
        args[8:16] = out[8:16]
        args[16:24] = out[16:24]
        args[24] = out[24]
    new_logits, _ = model.pv_forward(tuple(args[0:8]), s)
    new_logp = jax.nn.log_softmax(new_logits, axis=1)[:, 0]
    # Probability of the advantageous action must increase.
    assert float((new_logp - old_logp).mean()) > 0.0


def test_a2c_train_step_runs_and_is_finite():
    p = model.pv_init(6)
    z = pv_zeros(p)
    key = jax.random.PRNGKey(13)
    s = jax.random.normal(key, (B, S), jnp.float32)
    a = jnp.array(np.arange(B) % A, jnp.int32)
    adv = jax.random.normal(key, (B,), jnp.float32)
    ret = jax.random.normal(key, (B,), jnp.float32)
    out = model.a2c_train_step(
        *p, *z, *z, jnp.float32(0.0), s, a, adv, ret,
        jnp.float32(1e-3), jnp.float32(0.01),
    )
    assert len(out) == 27
    assert np.isfinite(float(out[25]))  # loss
    assert float(out[26]) > 0.0  # entropy positive for a fresh policy


def test_value_head_regresses_returns():
    # Train only on value loss (adv = 0): value predictions approach ret.
    p = model.pv_init(7)
    z = pv_zeros(p)
    key = jax.random.PRNGKey(17)
    s = jax.random.normal(key, (B, S), jnp.float32)
    a = jnp.zeros((B,), jnp.int32)
    adv = jnp.zeros((B,), jnp.float32)
    ret = jnp.ones((B,), jnp.float32) * 3.0
    args = [*p, *z, *z, jnp.float32(0.0), s, a, adv, ret,
            jnp.float32(1e-2), jnp.float32(0.0)]
    step = jax.jit(model.a2c_train_step)
    before = float(jnp.mean((model.pv_forward(p, s)[1] - ret) ** 2))
    for _ in range(20):
        out = step(*args)
        args[0:8] = out[:8]
        args[8:16] = out[8:16]
        args[16:24] = out[16:24]
        args[24] = out[24]
    after = float(jnp.mean((model.pv_forward(tuple(args[0:8]), s)[1] - ret) ** 2))
    assert after < before * 0.5, (before, after)


# ---------------------------------------------------------------------------
# AOT lowering sanity
# ---------------------------------------------------------------------------


def test_aot_entry_points_lower():
    from compile import aot

    eps = aot.entry_points()
    assert set(eps) >= {
        "q_init", "pv_init", "q_forward_b1", "q_forward_b64",
        "pv_forward_b1", "dqn_train_step", "ppo_train_step",
        "a2c_train_step", "mm_64", "mm_128", "mm_256", "mm_512",
    }
    # Lower a small one end-to-end and check it is valid HLO text.
    fn, specs = eps["q_forward_b1"]
    text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert "HloModule" in text
    assert "ENTRY" in text


def test_manifest_counts_match():
    from compile import aot

    for name, (fn, specs) in aot.entry_points().items():
        n = aot.num_outputs(fn, specs)
        assert n >= 1, name
        if name == "dqn_train_step":
            assert n == 21
        if name == "ppo_train_step":
            assert n == 28
        if name == "a2c_train_step":
            assert n == 27
